"""Roofline analysis from the dry-run artifacts.

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw
(cost_analysis and the parsed HLO are post-SPMD = per device, so the
"/chips" in the spec's formulas is already applied.)

Also reported: MODEL_FLOPS (analytic useful compute, 6·N_active·D for
training) and MODEL_FLOPS / HLO_FLOPs — the fraction of compiled compute
that is "useful" (exposes remat recompute, layer padding, whisper's
cond-duplicated paths, MoE dispatch overhead).
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.core.hardware import TPU_V5E
from repro.core.profiler import profile_arch

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
UNROLL_DIR = os.path.join(os.path.dirname(__file__), "results",
                          "dryrun_unroll")


def best_dir() -> str:
    """Prefer loop-aware (--unroll diff) records when they exist."""
    import glob as _g
    return UNROLL_DIR if _g.glob(os.path.join(UNROLL_DIR, "*.json")) \
        else DRYRUN_DIR

PEAK = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9         # bytes/s per chip
LINK_BW = 50e9         # bytes/s per ICI link


def model_flops_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    """Analytic useful FLOPs per device (6·N_active·D for training;
    forward-only for prefill; one token per sequence for decode, with the
    attention span set to the cache length)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        prof = profile_arch(cfg, seq=shape.seq_len)
        per_tok = prof.total_flops_fwd() + prof.head.flops_fwd
        total = 3.0 * per_tok * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        prof = profile_arch(cfg, seq=shape.seq_len)
        per_tok = prof.total_flops_fwd() + prof.head.flops_fwd
        total = per_tok * shape.global_batch * shape.seq_len
    else:  # decode: one new token attending the full cache
        prof = profile_arch(cfg, seq=2 * shape.seq_len)   # span = seq_len
        per_tok = prof.total_flops_fwd() + prof.head.flops_fwd
        total = per_tok * shape.global_batch
    return total / n_dev


def hbm_traffic_lb(arch: str, shape_name: str, M: int,
                   gated: bool = False) -> float:
    """Analytic LOWER bound on per-device HBM traffic per step.

    ``cost_analysis()``'s "bytes accessed" counts every producer-consumer
    edge (zero fusion residency) and overshoots HBM traffic by orders of
    magnitude, so it is reported as an upper bound only.  The lower bound
    counts what MUST move through HBM: stage weights re-read every pipeline
    tick (fwd + bwd + remat fwd), boundary/intermediate activations at ~8
    tensor passes per layer, and for decode the full KV/SSM cache read per
    generated token."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    S = cfg.stages
    ticks = M + S - 1
    prof = profile_arch(cfg, seq=min(shape.seq_len, 8192))
    stage_w = prof.total_bytes_weights() / S        # bf16 already (bpp=2)
    d = cfg.d_model
    Lps = -(-cfg.n_layers // S)
    n_batch_shards = 16                             # data axis
    if shape.kind == "train":
        tok_mb = shape.global_batch * shape.seq_len / n_batch_shards / M
        act = Lps * tok_mb * d * 2 * 8
        return ticks * (3 * stage_w + 3 * act)
    if shape.kind == "prefill":
        b_loc = max(1, shape.global_batch // n_batch_shards)
        tok_mb = b_loc * shape.seq_len / M
        act = Lps * tok_mb * d * 2 * 8
        cache_w = _cache_bytes_per_dev(cfg, shape, S)
        return ticks * (stage_w + act) + cache_w
    # decode: one token/sequence; cache read once, weights per tick —
    # or per VALID tick (M of them) when invalid ticks are cond-gated
    cache_r = _cache_bytes_per_dev(cfg, shape, S)
    b_loc = max(1, shape.global_batch // n_batch_shards)
    act = Lps * b_loc * d * 2 * 8
    eff_ticks = M if gated else ticks
    return eff_ticks * (stage_w + act) + cache_r


def _cache_bytes_per_dev(cfg, shape, S) -> float:
    """KV/SSM cache bytes per device (stage-sharded, tensor-sharded heads)."""
    L = cfg.n_layers
    per_layer = 0.0
    b_loc = max(1, shape.global_batch // 16)
    if cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        per_layer += b_loc * nh * s.head_dim * s.d_state * 2
    if cfg.attn_kind == "mla":
        per_layer += b_loc * shape.seq_len * (
            cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim) * 2
    elif cfg.attn_kind == "gqa":
        win = cfg.window or shape.seq_len
        n_global = sum(cfg.is_global_layer(i) for i in range(L))
        frac_g = n_global / L
        eff = frac_g * shape.seq_len + (1 - frac_g) * min(win, shape.seq_len)
        nkv = max(1, cfg.n_kv_heads // max(1, cfg.tensor))
        per_layer += 2 * b_loc * eff * nkv * cfg.resolved_head_dim * 2
    return per_layer * L / S


def analyse_record(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    flops = float(rec["cost"].get("flops", 0.0))
    nbytes = float(rec["cost"].get("bytes accessed", 0.0))
    coll = float(rec["collectives"]["total"])
    M = rec.get("n_microbatches") or 1
    t_compute = flops / PEAK
    t_memory_ub = nbytes / HBM_BW
    t_memory = hbm_traffic_lb(rec["arch"], rec["shape"], M,
                              gated=bool(rec.get("gated"))) / HBM_BW
    t_coll = coll / LINK_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    mflops = model_flops_per_device(rec["arch"], rec["shape"], n_dev)
    bound = max(terms.values())
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"], M=M,
        t_compute=t_compute, t_memory=t_memory, t_memory_ub=t_memory_ub,
        t_collective=t_coll,
        dominant=dominant,
        model_flops=mflops,
        useful_ratio=(mflops / flops) if flops else 0.0,
        roofline_fraction=(mflops / PEAK) / bound if bound else 0.0,
        collectives=rec["collectives"],
        hlo_flops=flops, hlo_bytes=nbytes,
    )


def load_all(mesh: str | None = None, dryrun_dir: str | None = None,
             include_overrides: bool = False) -> list[dict]:
    dryrun_dir = dryrun_dir or best_dir()
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if not include_overrides and rec.get("overrides"):
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        a = analyse_record(rec)
        if a:
            a["file"] = os.path.basename(path)
            out.append(a)
    return out


def pick_hillclimb_pairs(rows: list[dict]) -> dict:
    """The three mandated hillclimb targets (deduplicated): worst roofline
    fraction, most collective-bound, and most representative of the paper's
    technique (the train shape with the most pipeline p2p traffic — the
    deepest pipeline)."""
    single = [r for r in rows if r["mesh"] == "16x16"]
    picks: dict = {}
    used: set = set()

    def take(name, pool, key, biggest=True):
        pool = [r for r in pool if (r["arch"], r["shape"]) not in used]
        r = max(pool, key=key) if biggest else min(pool, key=key)
        used.add((r["arch"], r["shape"]))
        picks[name] = r

    take("worst_fraction", single, lambda r: r["roofline_fraction"],
         biggest=False)
    take("most_collective_bound", single,
         lambda r: r["t_collective"] / max(1e-12, max(r["t_compute"],
                                                      r["t_memory"])))
    take("most_representative",
         [r for r in single if r["shape"] == "train_4k"],
         lambda r: r["collectives"]["collective-permute"])
    return picks


def rows_csv(rows):
    out = []
    for r in rows:
        name = f"roofline.{r['mesh']}.{r['arch']}.{r['shape']}"
        bound_us = max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6
        out.append((name, round(bound_us, 3),
                    f"dom={r['dominant']} comp={r['t_compute']*1e6:.1f}us "
                    f"mem={r['t_memory']*1e6:.1f}us "
                    f"coll={r['t_collective']*1e6:.1f}us "
                    f"useful={r['useful_ratio']:.2f} "
                    f"frac={r['roofline_fraction']:.3f}"))
    return out


def main():
    rows = load_all()
    for name, val, extra in rows_csv(rows):
        print(f"{name},{val},{extra}")
    picks = pick_hillclimb_pairs(rows)
    for k, r in picks.items():
        print(f"hillclimb.{k},{r['arch']}/{r['shape']},"
              f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
