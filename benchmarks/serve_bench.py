"""Continuous-batching serving benchmark: open-loop arrivals vs baselines.

Drives a synthetic open-loop arrival process (requests arrive at fixed
engine-step gaps with mixed prompt lengths) through three policies over
the SAME compiled pipelined serve step:

* ``continuous``  — the `ContinuousEngine`: arrivals admitted into free
  cache slots as they land, chunked prefill interleaved with running
  decodes in one mixed op table per step;
* ``sequential``  — batch-1 semantics: one request in flight at a time,
  each run to completion before the next is admitted (the no-batching
  baseline; also the per-request *reference tokens* for the bit-identity
  check);
* ``one-shot``    — static batching: wait for every request to arrive,
  then run them all together (throughput-friendly, latency-hostile).

Reports p50/p99 request latency (engine steps and wall ms, measured from
each request's arrival) and aggregate generated tokens/sec, and verifies
the continuous run's tokens are bit-identical per request to the
sequential (single-request) reference.

CPU quickstart / CI gate:

    python benchmarks/serve_bench.py --dry-run
"""
import argparse
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.core import serve_sched as SS
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST


def build(args):
    cfg = get_config(args.arch).reduced(n_layers=args.layers,
                                        d_model=args.d_model)
    cfg = dataclasses.replace(cfg, stages=args.stages, tensor=args.tensor)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((args.data, args.stages, args.tensor),
                     ("data", "stage", "tensor"))
    plan = ST.plan_stages(cfg, virtual=1)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(args.seed), plan)
    pcfg = RT.PipelineConfig(n_microbatches=args.microbatches)
    step, _, cspecs, _ = RT.make_serve_step(
        cfg, mesh, plan, pcfg, max_len=args.max_len,
        global_batch=args.slots, q_len=args.chunk)

    def fresh_cache():
        return jax.jit(
            lambda: RT.init_pipeline_cache(cfg, plan, args.slots,
                                           args.max_len),
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       cspecs))()

    return cfg, mesh, plan, params, step, fresh_cache


def make_requests(cfg, args):
    rng = np.random.default_rng(args.seed)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(args.prompt_min, args.prompt_max + 1))
        reqs.append(SS.Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
            max_new=args.gen, arrival=i * args.arrival_gap))
    return reqs


def timed_engine(cfg, step, params, cache, n_slots, chunk):
    """Engine whose step is fenced and wall-clock stamped per step."""
    stamps = []

    def fenced(p, c, b):
        lg, c2 = step(p, c, b)
        jax.block_until_ready(lg)
        stamps.append(time.perf_counter())
        return lg, c2

    eng = SS.ContinuousEngine(cfg, fenced, params, cache, n_slots, chunk)
    return eng, stamps


def run_policy(policy, cfg, step, params, fresh_cache, reqs, args):
    """Run one admission policy; returns (retired, steps, wall_s, lat_ms)."""
    import copy
    true_arrival = {r.rid: r.arrival for r in reqs}
    reqs = copy.deepcopy(reqs)
    if policy == "one-shot":
        # static batching: collect the whole batch first, then launch
        t_batch = max(r.arrival for r in reqs)
        for r in reqs:
            r.arrival = t_batch
    eng, stamps = timed_engine(cfg, step, params, fresh_cache(),
                               args.slots, args.chunk)
    t0 = time.perf_counter()
    if policy == "sequential":
        done = []
        for r in sorted(reqs, key=lambda q: q.arrival):
            r.arrival = eng.steps_run  # admit strictly after the previous
            done += eng.run([r])
    else:
        done = eng.run(reqs)
    wall = time.perf_counter() - t0

    def step_wall(i):  # wall time at which engine step i finished
        return stamps[min(i, len(stamps) - 1)] - t0

    # per-request latency from TRUE arrival step to completion step
    lat_steps, lat_ms = {}, {}
    for r in done:
        a = true_arrival[r.rid]
        lat_steps[r.rid] = r.t_done - a + 1
        start = step_wall(a - 1) if a > 0 else 0.0
        lat_ms[r.rid] = (step_wall(r.t_done) - start) * 1e3
    return done, eng.steps_run, wall, lat_steps, lat_ms


def summarize(policy, done, steps, wall, lat_steps, lat_ms, args):
    toks = sum(len(r.generated) for r in done)
    ls = np.array(sorted(lat_steps.values()))
    lm = np.array(sorted(lat_ms.values()))
    tput = toks / max(wall, 1e-9)
    print(f"{policy:>11}: {steps:3d} steps  {wall*1e3:8.1f}ms  "
          f"{tput:7.1f} tok/s  "
          f"latency p50={np.percentile(ls, 50):.0f} steps "
          f"({np.percentile(lm, 50):.0f}ms)  "
          f"p99={np.percentile(ls, 99):.0f} steps "
          f"({np.percentile(lm, 99):.0f}ms)")
    return tput


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--data", type=int, default=2)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--tensor", type=int, default=2)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=24)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--arrival-gap", type=int, default=2,
                    help="engine steps between arrivals (open loop)")
    ap.add_argument("--mem-limit-mb", type=float, default=0.0,
                    help="gate the slot count by per-stage cache memory "
                         "(0 = ungated)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="small config, assert wins + bit-identity (CI)")
    args = ap.parse_args(argv)
    if args.dry_run:
        args.layers, args.d_model = 2, 64
        args.requests, args.gen = 4, 4
        args.chunk, args.slots, args.max_len = 4, 8, 48
        args.prompt_min, args.prompt_max = 4, 10

    if args.mem_limit_mb:
        # budget the SAME reduced config build() instantiates; slots are
        # sharded over data AND split into microbatches, so quantise to
        # a multiple of both
        rcfg = get_config(args.arch).reduced(n_layers=args.layers,
                                             d_model=args.d_model)
        quant = args.microbatches * args.data
        budget = SS.serve_slot_budget(
            rcfg, args.max_len, args.mem_limit_mb * 2**20,
            n_stages=args.stages, microbatches=quant)
        if budget < args.slots:
            print(f"slot budget: {args.slots} -> {budget} "
                  f"(mem limit {args.mem_limit_mb:.0f} MiB)")
            args.slots = max(quant, budget)

    cfg, mesh, plan, params, step, fresh_cache = build(args)
    reqs = make_requests(cfg, args)
    print(f"{args.arch}: {args.requests} requests, prompts "
          f"{args.prompt_min}-{args.prompt_max}, gen {args.gen}, "
          f"arrival gap {args.arrival_gap} steps, {args.slots} slots x "
          f"chunk {args.chunk}, mesh data={args.data} stage={args.stages} "
          f"tensor={args.tensor}")

    # warm-up: compile the mixed step AND the slot-reset once, outside
    # every timed region
    c0 = fresh_cache()
    lg, c0 = step(params, c0,
                  dict(tokens=np.zeros((args.slots, args.chunk), np.int32),
                       n_valid=np.zeros((args.slots,), np.int32)))
    jax.block_until_ready(lg)
    c0 = SS.reset_slot_offsets(c0, np.zeros((args.slots,), bool))
    jax.block_until_ready(jax.tree.leaves(c0)[0])
    del c0

    results, tokens = {}, {}
    for policy in ("sequential", "one-shot", "continuous"):
        done, steps, wall, lat_s, lat_ms = run_policy(
            policy, cfg, step, params, fresh_cache, reqs, args)
        results[policy] = summarize(policy, done, steps, wall, lat_s,
                                    lat_ms, args)
        tokens[policy] = {r.rid: list(r.generated) for r in done}

    ident = tokens["continuous"] == tokens["sequential"]
    print(f"bit-identity continuous == single-request reference: {ident}")
    assert ident, "continuous batching changed request tokens"
    if args.dry_run:
        assert results["continuous"] > results["sequential"], \
            (results["continuous"], results["sequential"])
        print("PASS (continuous beats sequential batch-1, tokens "
              "bit-identical)")
    return results


if __name__ == "__main__":
    main()
