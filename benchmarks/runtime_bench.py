"""Wall-clock benchmark of the pipeline runtimes per schedule.

Runs the instruction-stream runtime (``runtime='stream'``) on 8 fake CPU
devices for each schedule, measures the per-step wall-clock, and checks
the measured ranking against ``simulate_costs`` fed the MEASURED per-op
durations — the planning→execution conformance claim: the simulator's
timing model, built from what the ops actually cost on this host, must
predict the order the runtimes realise.

Per-op durations are measured on a single-device stage proxy exactly as
the runtime executes them (structural stage-remat — every backward op
re-runs the stage forward under ``jax.vjp``):

* ``F``  — the stage forward;
* two-op ``B``      — recompute + full vjp (params and input);
* zero-bubble ``B`` — recompute + input-only vjp;
* zero-bubble ``W`` — recompute + params-only vjp.

So the zero-bubble family pays the recompute twice (once in B, once in
W): on hardware where W hides in drain bubbles that is the price of a
shorter critical path, and the simulator sees the same inflated costs —
measured and simulated rankings must still agree.

Usage::

    python benchmarks/runtime_bench.py [--assert-ranking] [--csv]

Prints one ``schedule,sim_makespan,measured_ms`` row per schedule plus
the two rankings.  ``--assert-ranking`` exits nonzero when a pair the
simulator separates by more than ``SIM_TIE`` is measured in the opposite
order by more than ``MEAS_SLACK`` — the CI conformance gate.
"""
import argparse
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCHEDULES = ("1f1b", "dapple", "zb-h1", "zb-auto")
SIM_TIE = 0.05     # sim gap below 5% is a tie: no ordering required
MEAS_SLACK = 1.10  # measured may violate a sim ordering by <= 10% noise


def _stage_proxy(cfg, mesh, plan):
    """One stage's forward as the runtime applies it, on a single
    micro-batch — the timing unit of every schedule op."""
    import jax
    import jax.numpy as jnp
    from repro.pipeline import runtime as RT
    from repro.pipeline import stage as ST

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    lp = jax.tree.map(lambda a: a[0], params["layers"])  # [Lps, ...] stage 0
    smeta = jax.tree.map(lambda a: a[0], ST.stacked_meta(cfg, plan))
    mb, T = 1, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (mb, T, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))

    def fwd(lp_, x_):
        y, a, _ = RT.apply_stage(cfg, lp_, smeta, x_, pos=pos, cache=None)
        return y, a

    return fwd, lp, x


def _time(fn, *args, iters=20):
    import jax
    out = fn(*args)
    jax.block_until_ready(out)          # compile outside the timed region
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def measure_op_durations(cfg, mesh, plan):
    """(t_f, t_full, t_dx, t_dw): the four op costs of the runtime's
    structural stage-remat execution, measured on this host."""
    import jax

    fwd, lp, x = _stage_proxy(cfg, mesh, plan)
    ones = lambda t: jax.tree.map(lambda a: a.astype(float) * 0 + 1, t)

    @jax.jit
    def f_op(lp_, x_):
        return fwd(lp_, x_)[0]

    @jax.jit
    def b_full(lp_, x_):                # two-op backward: recompute + vjp
        (y, a), vjp = jax.vjp(lambda l, xx: fwd(l, xx), lp_, x_)
        return vjp((ones(y), 1.0))

    @jax.jit
    def b_dx(lp_, x_):                  # zb B: recompute + input-only vjp
        (y, a), vjp = jax.vjp(lambda xx: fwd(lp_, xx), x_)
        return vjp((ones(y), 1.0))

    @jax.jit
    def b_dw(lp_, x_):                  # zb W: recompute + params-only vjp
        (y, a), vjp = jax.vjp(lambda l: fwd(l, x_), lp_)
        return vjp((ones(y), 1.0))

    return (_time(f_op, lp, x), _time(b_full, lp, x),
            _time(b_dx, lp, x), _time(b_dw, lp, x))


def sim_makespans(M, S, t_f, t_full, t_dx, t_dw):
    """simulate_costs under the measured durations, per schedule."""
    from repro.core import schedplan as SP
    from repro.core.simulator import simulate_costs
    out = {}
    for sched in SCHEDULES:
        if SP.build_schedule(sched, M, S, 1).has_w:
            b = t_dx + t_dw
            costs = SP.StageCosts.uniform_costs(S, t_f, b, w_frac=t_dw / b)
        else:
            costs = SP.StageCosts.uniform_costs(S, t_f, t_full)
        out[sched] = simulate_costs(sched, M, S, costs).makespan
    return out


def measured_walltimes(cfg, mesh, plan, M, runtime="stream", steps=10):
    """Per-schedule best wall-clock of the jitted train step."""
    import jax
    import numpy as np
    from repro.pipeline import runtime as RT
    from repro.pipeline import stage as ST

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    kt, kl = jax.random.split(jax.random.PRNGKey(3))
    B, T = M, 64
    batch = dict(tokens=jax.random.randint(kt, (B, T), 0, cfg.vocab),
                 labels=jax.random.randint(kl, (B, T), 0, cfg.vocab))
    out = {}
    for sched in SCHEDULES:
        pcfg = RT.PipelineConfig(n_microbatches=M, schedule=sched,
                                 runtime=runtime)
        step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
        loss, grads = step(params, batch)          # compile + sanity
        assert np.isfinite(float(loss)), (sched, float(loss))
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(steps):
                loss, grads = step(params, batch)
            jax.block_until_ready(loss)
            best = min(best, (time.perf_counter() - t0) / steps)
        out[sched] = best
    return out


def check_ranking(sim, meas):
    """Every pair the simulator separates by > SIM_TIE must be measured
    in the same order (up to MEAS_SLACK noise).  Returns violations."""
    bad = []
    names = list(sim)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            lo, hi = (a, b) if sim[a] <= sim[b] else (b, a)
            if sim[hi] - sim[lo] <= SIM_TIE * sim[hi]:
                continue                           # sim tie: no constraint
            if meas[lo] > meas[hi] * MEAS_SLACK:
                bad.append((lo, hi, sim[lo], sim[hi], meas[lo], meas[hi]))
    return bad


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--runtime", default="stream",
                    choices=("ticks", "stream"))
    ap.add_argument("--assert-ranking", action="store_true")
    args = ap.parse_args(argv)

    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.pipeline import stage as ST

    S, M = args.stages, args.microbatches
    assert jax.device_count() >= S, \
        f"need {S} devices (XLA_FLAGS fake-device mesh), " \
        f"have {jax.device_count()}"
    cfg = get_config("llama3.2-1b").reduced(n_layers=args.layers,
                                            d_model=128)
    cfg = dataclasses.replace(cfg, stages=S, tensor=1)
    mesh = make_mesh((1, S, 1), ("data", "stage", "tensor"))
    plan = ST.plan_stages(cfg)

    t_f, t_full, t_dx, t_dw = measure_op_durations(cfg, mesh, plan)
    print(f"# op durations (ms): F={t_f*1e3:.3f} B_full={t_full*1e3:.3f} "
          f"B_dx={t_dx*1e3:.3f} W_dw={t_dw*1e3:.3f}")
    sim = sim_makespans(M, S, t_f, t_full, t_dx, t_dw)
    meas = measured_walltimes(cfg, mesh, plan, M, runtime=args.runtime)

    print("schedule,sim_makespan_ms,measured_ms")
    for sched in SCHEDULES:
        print(f"{sched},{sim[sched]*1e3:.3f},{meas[sched]*1e3:.3f}")
    rank = lambda d: ",".join(sorted(d, key=d.get))
    print(f"# sim ranking:      {rank(sim)}")
    print(f"# measured ranking: {rank(meas)}")
    bad = check_ranking(sim, meas)
    for (lo, hi, slo, shi, mlo, mhi) in bad:
        print(f"# RANKING VIOLATION: sim says {lo} < {hi} "
              f"({slo*1e3:.2f} < {shi*1e3:.2f} ms) but measured "
              f"{mlo*1e3:.2f} > {mhi*1e3:.2f} ms")
    if not bad:
        print("# RANKING OK")
    if args.assert_ranking and bad:
        sys.exit(1)
    return sim, meas


if __name__ == "__main__":
    main()
