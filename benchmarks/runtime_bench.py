"""Wall-clock benchmark of the pipeline runtimes per schedule.

Runs the instruction-stream runtime (``runtime='stream'``) on 8 fake CPU
devices for each schedule, measures the per-step wall-clock, and checks
the measured ranking against ``simulate_costs`` fed the MEASURED per-op
durations — the planning→execution conformance claim: the simulator's
timing model, built from what the ops actually cost on this host, must
predict the order the runtimes realise.

Per-op durations are measured on a single-device stage proxy exactly as
the runtime executes them (structural stage-remat — every backward op
re-runs the stage forward under ``jax.vjp``):

* ``F``  — the stage forward;
* two-op ``B``      — recompute + full vjp (params and input);
* zero-bubble ``B`` — recompute + input-only vjp;
* zero-bubble ``W`` — recompute + params-only vjp.

So the zero-bubble family pays the recompute twice (once in B, once in
W): on hardware where W hides in drain bubbles that is the price of a
shorter critical path, and the simulator sees the same inflated costs —
measured and simulated rankings must still agree.

Usage::

    python benchmarks/runtime_bench.py [--assert-ranking] [--data D]

Prints one ``schedule,sim_makespan,measured_ms`` row per schedule plus
the two rankings.  ``--assert-ranking`` exits nonzero when a pair the
simulator separates by more than ``SIM_TIE`` is measured in the opposite
order by more than ``MEAS_SLACK`` — the CI conformance gate.

``--data D`` (D > 1) switches to the grad-sync report: a (D data x S
stage) mesh, each schedule stepped under ``grad_sync='end'`` and
``'overlap'`` on the stream runtime, one row per schedule with the
measured wall-clock of both paths next to the simulator's predicted
exposed/hidden sync split (``simulate_costs`` fed the measured per-op
durations and the measured data-fabric AR cost).  The ranking gate then
compares the OVERLAPPED measurements against the overlapped sim
makespans, and flags any schedule whose overlap path measures slower
than its own sync-at-end path beyond noise.
"""
import argparse
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SCHEDULES = ("1f1b", "dapple", "zb-h1", "zb-auto")
SIM_TIE = 0.05     # sim gap below 5% is a tie: no ordering required
MEAS_SLACK = 1.10  # measured may violate a sim ordering by <= 10% noise
# overlap-vs-end is a gross-regression gate only: on fake CPU devices
# the AR bucket is a memcpy (nothing to hide) while the per-slot gate
# costs real dispatch overhead, so small measured losses are expected —
# the gate exists to catch the overlap path recompiling or serializing
OVERLAP_SLACK = 1.25


def _stage_proxy(cfg, mesh, plan):
    """One stage's forward as the runtime applies it, on a single
    micro-batch — the timing unit of every schedule op."""
    import jax
    import jax.numpy as jnp
    from repro.pipeline import runtime as RT
    from repro.pipeline import stage as ST

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    lp = jax.tree.map(lambda a: a[0], params["layers"])  # [Lps, ...] stage 0
    smeta = jax.tree.map(lambda a: a[0], ST.stacked_meta(cfg, plan))
    mb, T = 1, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (mb, T, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))

    def fwd(lp_, x_):
        y, a, _ = RT.apply_stage(cfg, lp_, smeta, x_, pos=pos, cache=None)
        return y, a

    return fwd, lp, x


def _time(fn, *args, iters=20):
    import jax
    out = fn(*args)
    jax.block_until_ready(out)          # compile outside the timed region
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best


def measure_op_durations(cfg, mesh, plan):
    """(t_f, t_full, t_dx, t_dw): the four op costs of the runtime's
    structural stage-remat execution, measured on this host."""
    import jax

    fwd, lp, x = _stage_proxy(cfg, mesh, plan)
    ones = lambda t: jax.tree.map(lambda a: a.astype(float) * 0 + 1, t)

    @jax.jit
    def f_op(lp_, x_):
        return fwd(lp_, x_)[0]

    @jax.jit
    def b_full(lp_, x_):                # two-op backward: recompute + vjp
        (y, a), vjp = jax.vjp(lambda l, xx: fwd(l, xx), lp_, x_)
        return vjp((ones(y), 1.0))

    @jax.jit
    def b_dx(lp_, x_):                  # zb B: recompute + input-only vjp
        (y, a), vjp = jax.vjp(lambda xx: fwd(lp_, xx), x_)
        return vjp((ones(y), 1.0))

    @jax.jit
    def b_dw(lp_, x_):                  # zb W: recompute + params-only vjp
        (y, a), vjp = jax.vjp(lambda l: fwd(l, x_), lp_)
        return vjp((ones(y), 1.0))

    return (_time(f_op, lp, x), _time(b_full, lp, x),
            _time(b_dx, lp, x), _time(b_dw, lp, x))


def _measured_costs(M, S, sched, t_f, t_full, t_dx, t_dw):
    from repro.core import schedplan as SP
    if SP.build_schedule(sched, M, S, 1).has_w:
        b = t_dx + t_dw
        return SP.StageCosts.uniform_costs(S, t_f, b, w_frac=t_dw / b)
    return SP.StageCosts.uniform_costs(S, t_f, t_full)


def sim_makespans(M, S, t_f, t_full, t_dx, t_dw):
    """simulate_costs under the measured durations, per schedule."""
    from repro.core.simulator import simulate_costs
    out = {}
    for sched in SCHEDULES:
        costs = _measured_costs(M, S, sched, t_f, t_full, t_dx, t_dw)
        out[sched] = simulate_costs(sched, M, S, costs).makespan
    return out


def sim_grad_sync(M, S, t_f, t_full, t_dx, t_dw, ar):
    """Per schedule: (base, overlapped, sequential) makespans under the
    measured op durations and the measured AR bucket cost — the
    simulator replaying the AR-op plan on the shared data fabric."""
    from repro.core.simulator import simulate_costs
    out = {}
    for sched in SCHEDULES:
        costs = _measured_costs(M, S, sched, t_f, t_full, t_dx, t_dw)
        base = simulate_costs(sched, M, S, costs).makespan
        ov = simulate_costs(sched, M, S, costs, ar=ar,
                            grad_sync=True).makespan
        out[sched] = (base, ov, base + S * ar)
    return out


def measure_ar_duration(mesh, n_elems, dp):
    """Measured cost of one AR bucket on the data fabric: the chunked
    ``psum_scatter`` + ``all_gather`` exactly as the stream runtime
    executes an AR slot, over a flat bucket of ``n_elems`` floats."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    x = jnp.zeros((n_elems + (-n_elems) % dp,), jnp.float32)

    def rs_ag(v):
        red = lax.psum_scatter(v, "data", scatter_dimension=0, tiled=True)
        return lax.all_gather(red, "data", axis=0, tiled=True)

    # RS+AG leaves the value replicated over data, but the rep checker
    # can't infer that through psum_scatter — disable it
    f = jax.jit(shard_map(rs_ag, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_rep=False))
    return _time(f, x)


def measured_walltimes(cfg, mesh, plan, M, runtime="stream", steps=10,
                       dp=1, grad_sync="auto", ar_groups=1):
    """Per-schedule best wall-clock of the jitted train step."""
    import jax
    import numpy as np
    from repro.pipeline import runtime as RT
    from repro.pipeline import stage as ST

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    kt, kl = jax.random.split(jax.random.PRNGKey(3))
    B, T = M * dp, 64
    batch = dict(tokens=jax.random.randint(kt, (B, T), 0, cfg.vocab),
                 labels=jax.random.randint(kl, (B, T), 0, cfg.vocab))
    out = {}
    for sched in SCHEDULES:
        pcfg = RT.PipelineConfig(n_microbatches=M, schedule=sched,
                                 runtime=runtime, grad_sync=grad_sync,
                                 ar_groups=ar_groups)
        step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
        loss, grads = step(params, batch)          # compile + sanity
        assert np.isfinite(float(loss)), (sched, float(loss))
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(steps):
                loss, grads = step(params, batch)
            jax.block_until_ready(loss)
            best = min(best, (time.perf_counter() - t0) / steps)
        out[sched] = best
    return out


def check_ranking(sim, meas):
    """Every pair the simulator separates by > SIM_TIE must be measured
    in the same order (up to MEAS_SLACK noise).  Returns violations."""
    bad = []
    names = list(sim)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            lo, hi = (a, b) if sim[a] <= sim[b] else (b, a)
            if sim[hi] - sim[lo] <= SIM_TIE * sim[hi]:
                continue                           # sim tie: no constraint
            if meas[lo] > meas[hi] * MEAS_SLACK:
                bad.append((lo, hi, sim[lo], sim[hi], meas[lo], meas[hi]))
    return bad


def grad_sync_report(args, cfg, mesh, plan, M, S, dp,
                     t_f, t_full, t_dx, t_dw):
    """The ``--data`` mode: measured 'end' vs 'overlap' wall-clock per
    schedule next to the simulator's exposed/hidden sync split."""
    import jax
    import numpy as np
    from repro.pipeline import stage as ST

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    n_elems = sum(int(np.prod(a.shape[1:]))
                  for a in jax.tree.leaves(params["layers"]))
    ar = measure_ar_duration(mesh, n_elems, dp)
    print(f"# AR bucket ({n_elems} floats, dp={dp}): {ar*1e3:.3f} ms")

    sim = sim_grad_sync(M, S, t_f, t_full, t_dx, t_dw, ar)
    end = measured_walltimes(cfg, mesh, plan, M, dp=dp, grad_sync="end")
    ov = measured_walltimes(cfg, mesh, plan, M, dp=dp, grad_sync="overlap")

    print("schedule,sim_exposed_ms,sim_hidden_ms,"
          "end_ms,overlap_ms,measured_saved_ms")
    for sched in SCHEDULES:
        base, sov, seq = sim[sched]
        exposed, hidden = sov - base, seq - sov
        print(f"{sched},{exposed*1e3:.3f},{hidden*1e3:.3f},"
              f"{end[sched]*1e3:.3f},{ov[sched]*1e3:.3f},"
              f"{(end[sched] - ov[sched])*1e3:.3f}")

    if getattr(args, "ar_groups", 1) > 1:
        _grouped_ar_report(args, cfg, mesh, plan, M, S, dp,
                           t_f, t_full, t_dx, t_dw, ar, sim, ov)

    sim_ov = {s: v[1] for s, v in sim.items()}
    rank = lambda d: ",".join(sorted(d, key=d.get))
    print(f"# sim ranking (overlapped):      {rank(sim_ov)}")
    print(f"# measured ranking (overlapped): {rank(ov)}")
    bad = check_ranking(sim_ov, ov)
    for (lo, hi, slo, shi, mlo, mhi) in bad:
        print(f"# RANKING VIOLATION: sim says {lo} < {hi} "
              f"({slo*1e3:.2f} < {shi*1e3:.2f} ms) but measured "
              f"{mlo*1e3:.2f} > {mhi*1e3:.2f} ms")
    # the overlap path must never cost GROSSLY more than its own
    # sync-at-end path (see OVERLAP_SLACK: fake-device collectives are
    # free, so we gate on gross regression, not on realized savings)
    for sched in SCHEDULES:
        if ov[sched] > end[sched] * OVERLAP_SLACK:
            bad.append((sched, "end", sim_ov[sched], sim[sched][2],
                        ov[sched], end[sched]))
            print(f"# OVERLAP REGRESSION: {sched} overlap "
                  f"{ov[sched]*1e3:.2f} ms > end "
                  f"{end[sched]*1e3:.2f} ms * {OVERLAP_SLACK}")
    if not bad:
        print("# RANKING OK")
    if args.assert_ranking and bad:
        sys.exit(1)
    return sim, ov


def _grouped_ar_report(args, cfg, mesh, plan, M, S, dp,
                       t_f, t_full, t_dx, t_dw, ar, sim, ov):
    """The ``--ar-groups`` satellite report: split each device's AR
    bucket into G per-layer-group buckets released as each group's W
    retires mid-drain.  Shows the closed-form exposed-sync drop
    (``eval_grad_sync(groups=G)``) next to the measured wall-clock of
    the grouped overlap path, and gates on the drop being monotone."""
    from repro.core import schedplan as SP
    from repro.core.schedules import eval_grad_sync

    G = args.ar_groups
    ovg = measured_walltimes(cfg, mesh, plan, M, dp=dp,
                             grad_sync="overlap", ar_groups=G)
    print(f"schedule,sim_exposed_g1_ms,sim_exposed_g{G}_ms,"
          f"overlap_g1_ms,overlap_g{G}_ms")
    for sched in SCHEDULES:
        if SP.build_schedule(sched, M, S, 1).has_w:
            b = t_dx + t_dw
            wf = t_dw / b
        else:
            b, wf = t_full, 0.5
        e1 = eval_grad_sync(sched, M, S, t_f, b, ar, w_frac=wf).exposed
        eg = eval_grad_sync(sched, M, S, t_f, b, ar, w_frac=wf,
                            groups=G).exposed
        assert eg <= e1 + 1e-12, (sched, e1, eg)
        print(f"{sched},{e1*1e3:.3f},{eg*1e3:.3f},"
              f"{ov[sched]*1e3:.3f},{ovg[sched]*1e3:.3f}")
    print(f"# GROUPED-AR OK: exposed(G={G}) <= exposed(1) "
          f"for all schedules")


def tp_report(args):
    """The ``--tp`` dry-run gate: uniform tp=2 plans executed on the
    real ``tensor`` axis by BOTH runtimes — losses and gradients must
    be bit-equal across ticks/stream (the 3D planner's uniform
    candidates are executable), with the per-runtime wall-clock
    reported."""
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.pipeline import runtime as RT
    from repro.pipeline import stage as ST

    S, M, tp = args.stages, args.microbatches, 2
    assert jax.device_count() >= S * tp, \
        f"--tp needs {S * tp} devices, have {jax.device_count()}"
    cfg = get_config("llama3.2-1b").reduced(n_layers=args.layers,
                                            d_model=128)
    cfg = dataclasses.replace(cfg, stages=S, tensor=tp)
    mesh = make_mesh((1, S, tp), ("data", "stage", "tensor"))
    plan = ST.plan_stages(cfg)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    kt, kl = jax.random.split(jax.random.PRNGKey(3))
    batch = dict(tokens=jax.random.randint(kt, (M, 64), 0, cfg.vocab),
                 labels=jax.random.randint(kl, (M, 64), 0, cfg.vocab))
    bad = False
    print("schedule,ticks_ms,stream_ms,bitequal")
    for sched in ("1f1b", "zb-h1"):
        outs, times = {}, {}
        for runtime in ("ticks", "stream"):
            pcfg = RT.PipelineConfig(n_microbatches=M, schedule=sched,
                                     runtime=runtime)
            step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
            loss, grads = step(params, batch)
            assert np.isfinite(float(loss)), (sched, runtime, float(loss))
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                for _ in range(5):
                    loss, grads = step(params, batch)
                jax.block_until_ready(loss)
                best = min(best, (time.perf_counter() - t0) / 5)
            outs[runtime] = (float(loss), jax.tree.map(np.asarray, grads))
            times[runtime] = best
        (lt, gt), (ls, gs) = outs["ticks"], outs["stream"]
        ok = ls == lt
        if ok:
            try:
                jax.tree.map(
                    lambda a, b: np.testing.assert_array_equal(a, b),
                    gs, gt)
            except AssertionError:
                ok = False
        print(f"{sched},{times['ticks']*1e3:.3f},"
              f"{times['stream']*1e3:.3f},{'yes' if ok else 'NO'}")
        bad |= not ok
    if bad:
        print("# TP DRY-RUN FAILED: ticks/stream mismatch")
        sys.exit(1)
    print("# TP DRY-RUN OK")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--runtime", default="stream",
                    choices=("ticks", "stream"))
    ap.add_argument("--data", type=int, default=1,
                    help="data-parallel degree; > 1 switches to the "
                         "grad_sync 'end' vs 'overlap' exposed-sync "
                         "report (stream runtime only)")
    ap.add_argument("--assert-ranking", action="store_true")
    ap.add_argument("--tp", action="store_true",
                    help="tp=2 dry-run gate: execute uniform-TP plans "
                         "on the real tensor axis under both runtimes "
                         "and require bit-equal losses/gradients")
    ap.add_argument("--ar-groups", type=int, default=1,
                    help="with --data > 1: also report the per-layer-"
                         "group AR bucket split (G buckets per device "
                         "released as each group's W retires) — closed-"
                         "form exposed-sync drop + measured wall-clock")
    args = ap.parse_args(argv)

    if args.tp:
        # bit-equality across differently structured programs needs
        # single-threaded contractions (see tests/harness_pipe.py);
        # set before the first jax import locks the backend
        if "--xla_cpu_multi_thread_eigen" not in os.environ["XLA_FLAGS"]:
            os.environ["XLA_FLAGS"] += " --xla_cpu_multi_thread_eigen=false"
        return tp_report(args)

    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.pipeline import stage as ST

    S, M, dp = args.stages, args.microbatches, args.data
    assert jax.device_count() >= dp * S, \
        f"need {dp * S} devices (XLA_FLAGS fake-device mesh), " \
        f"have {jax.device_count()}"
    assert dp == 1 or args.runtime == "stream", \
        "--data > 1 overlaps the sync in-schedule: stream runtime only"
    cfg = get_config("llama3.2-1b").reduced(n_layers=args.layers,
                                            d_model=128)
    cfg = dataclasses.replace(cfg, stages=S, tensor=1)
    mesh = make_mesh((dp, S, 1), ("data", "stage", "tensor"))
    plan = ST.plan_stages(cfg)

    t_f, t_full, t_dx, t_dw = measure_op_durations(cfg, mesh, plan)
    print(f"# op durations (ms): F={t_f*1e3:.3f} B_full={t_full*1e3:.3f} "
          f"B_dx={t_dx*1e3:.3f} W_dw={t_dw*1e3:.3f}")

    if dp > 1:
        return grad_sync_report(args, cfg, mesh, plan, M, S, dp,
                                t_f, t_full, t_dx, t_dw)

    sim = sim_makespans(M, S, t_f, t_full, t_dx, t_dw)
    meas = measured_walltimes(cfg, mesh, plan, M, runtime=args.runtime)

    print("schedule,sim_makespan_ms,measured_ms")
    for sched in SCHEDULES:
        print(f"{sched},{sim[sched]*1e3:.3f},{meas[sched]*1e3:.3f}")
    rank = lambda d: ",".join(sorted(d, key=d.get))
    print(f"# sim ranking:      {rank(sim)}")
    print(f"# measured ranking: {rank(meas)}")
    bad = check_ranking(sim, meas)
    for (lo, hi, slo, shi, mlo, mhi) in bad:
        print(f"# RANKING VIOLATION: sim says {lo} < {hi} "
              f"({slo*1e3:.2f} < {shi*1e3:.2f} ms) but measured "
              f"{mlo*1e3:.2f} > {mhi*1e3:.2f} ms")
    if not bad:
        print("# RANKING OK")
    if args.assert_ranking and bad:
        sys.exit(1)
    return sim, meas


if __name__ == "__main__":
    main()
