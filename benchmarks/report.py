"""Render §Roofline of EXPERIMENTS.md from the dry-run artifacts.

Reads loop-aware costs from results/dryrun_unroll (falling back to the
plain dry-run) plus memory analysis from results/dryrun, and rewrites the
block between the ROOFLINE_TABLE markers in EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline

HERE = os.path.dirname(__file__)
UNROLL_DIR = os.path.join(HERE, "results", "dryrun_unroll")
PLAIN_DIR = os.path.join(HERE, "results", "dryrun")
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")


def _fmt_t(sec: float) -> str:
    if sec >= 0.1:
        return f"{sec*1e3:.0f}ms"
    if sec >= 1e-4:
        return f"{sec*1e3:.2f}ms"
    return f"{sec*1e6:.0f}us"


def memory_by_key() -> dict:
    out = {}
    for path in glob.glob(os.path.join(PLAIN_DIR, "*.json")):
        r = json.load(open(path))
        if r.get("status") != "ok" or r.get("overrides"):
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        m = r.get("memory") or {}
        args = m.get("argument_bytes") or 0
        tmp = m.get("temp_bytes") or 0
        out[key] = (args + tmp) / 1e9
    return out


def skips() -> list[tuple[str, str]]:
    out = []
    for path in glob.glob(os.path.join(PLAIN_DIR, "*.json")):
        r = json.load(open(path))
        if r.get("status") == "skip" and r["mesh"] == "16x16":
            out.append((r["arch"], r["shape"]))
    return sorted(out)


def render() -> str:
    rows = roofline.load_all(mesh="16x16")
    mem = memory_by_key()
    lines = [
        "| arch | shape | M | compute | memory (lb / HLO-ub) | collective |"
        " dominant | GB/dev | useful | frac | what would move the dominant"
        " term |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        gb = mem.get((r["arch"], r["shape"], r["mesh"]))
        note = _advice(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['M']} "
            f"| {_fmt_t(r['t_compute'])} "
            f"| {_fmt_t(r['t_memory'])} / {_fmt_t(r['t_memory_ub'])} "
            f"| {_fmt_t(r['t_collective'])} | {r['dominant']} "
            f"| {gb:.1f} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {note} |"
            if gb is not None else
            f"| {r['arch']} | {r['shape']} | {r['M']} "
            f"| {_fmt_t(r['t_compute'])} "
            f"| {_fmt_t(r['t_memory'])} / {_fmt_t(r['t_memory_ub'])} "
            f"| {_fmt_t(r['t_collective'])} | {r['dominant']} "
            f"| - | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {note} |")
    lines.append("")
    lines.append("Skipped at baseline (policy, DESIGN.md §5): "
                 + ", ".join(f"{a}/{s}" for a, s in skips()) + ".")
    picks = roofline.pick_hillclimb_pairs(rows)
    lines.append("")
    lines.append("Hillclimb picks: "
                 + "; ".join(f"**{k}** → {v['arch']}/{v['shape']} "
                             f"(dom={v['dominant']}, "
                             f"frac={v['roofline_fraction']:.3f})"
                             for k, v in picks.items()) + ".")
    return "\n".join(lines)


def _advice(r) -> str:
    if r["dominant"] == "memory":
        if r["shape"].startswith("decode") or r["shape"] == "long_500k":
            return "weight/cache streaming per token: fewer stages (fewer " \
                   "weight re-reads), shard cache wider, quantise cache"
        return "weights re-read every tick: raise M, fewer stages"
    if r["dominant"] == "collective":
        return "shrink tensor psum traffic / lower MoE a2a payload " \
               "(capacity factor)"
    return "raise M to cut (M+S-1)/M fill-drain waste; relax remat"


def inject(md_path: str = EXP):
    table = render()
    src = open(md_path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    pre, _, post = src.partition(marker)
    # replace everything up to the next section heading
    rest = post.split("\n## ", 1)
    tail = ("\n## " + rest[1]) if len(rest) > 1 else ""
    open(md_path, "w").write(pre + marker + "\n\n" + table + "\n" + tail)
    print(f"wrote roofline table ({table.count(chr(10))} lines) to {md_path}")


if __name__ == "__main__":
    inject()
