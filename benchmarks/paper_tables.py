"""Reproductions of the paper's tables (analytic, on the paper's own
hardware models — the same methodology the paper uses for its FPGA
numbers).  One function per table; each returns rows of
(name, value, derived-metric) printed as CSV by benchmarks.run.
"""
from __future__ import annotations

import math

from repro.core import schedules as S
from repro.core.explorer import (dp_time_and_memory, explore, gpipe_time,
                                 pipedream_time)
from repro.core.hardware import (V100, VCU118, VCU129, DeviceSpec,
                                 heterogeneous_cluster, homogeneous_cluster)
from repro.core.profiler import (profile_gnmt, profile_gnmt_L,
                                 profile_resnet50, profile_vgg16)
from repro.core.simulator import simulate


def table1_async_schedules():
    """Table 1: 1F1B-AS vs FBP-AS closed forms, cross-checked against the
    discrete-event simulator."""
    rows = []
    M, N, F, B, a, w = 16, 4, 1.0, 2.0, 4.0, 10.0
    for name in ("1F1B-AS", "FBP-AS"):
        ev = S.SCHEDULES[name](M, N, F, B, 0.0, a, w)
        sim = simulate(name, M, N, F, B, 0.0)
        rows.append((f"table1.{name}.minibatch_time", ev.minibatch_time,
                     f"sim={sim.makespan}"))
        rows.append((f"table1.{name}.bubble", ev.bubble_fraction,
                     f"feat_mem_stage1={ev.features_memory[0]}"))
        rows.append((f"table1.{name}.bandwidth", ev.bandwidth_demand,
                     f"weights_mem={ev.weights_memory}"))
    return rows


def table_interleaved():
    """Beyond-paper column: interleaved 1F1B-I vs 1F1B-AS at the same
    (M, N) — the bubble shrinks by the interleave depth V while boundary
    bandwidth demand grows by V; cross-checked against the simulator."""
    rows = []
    M, N, F, B, a, w = 16, 4, 1.0, 2.0, 4.0, 10.0
    base = S.eval_1f1b_as(M, N, F, B, 0.0, a, w)
    rows.append(("tableI.1F1B-AS.bubble", base.bubble_fraction,
                 f"time={base.minibatch_time}"))
    for V in (2, 4):
        ev = S.eval_1f1b_interleaved(M, N, F, B, 0.0, a, w, V=V)
        sim = simulate("1F1B-I", M, N, F, B, 0.0, V=V)
        rows.append((f"tableI.1F1B-I.V{V}.minibatch_time", ev.minibatch_time,
                     f"sim={sim.makespan}"))
        rows.append((f"tableI.1F1B-I.V{V}.bubble", ev.bubble_fraction,
                     f"vs_1F1B-AS={base.bubble_fraction:.4f} "
                     f"feat_mem_stage1={ev.features_memory[0]} "
                     f"bandwidth={ev.bandwidth_demand}"))
        # memory-lean variant: identical makespan, (V-1)N features term
        ml = S.eval_1f1b_interleaved_memlean(M, N, F, B, 0.0, a, w, V=V)
        sim_ml = simulate("1F1B-I-ML", M, N, F, B, 0.0, V=V)
        rows.append((f"tableI.1F1B-I-ML.V{V}.feat_mem_stage1",
                     ml.features_memory[0],
                     f"vs_streaming={ev.features_memory[0]} "
                     f"sim_peak_live_stage1={sim_ml.peak_live[0]} "
                     f"time={ml.minibatch_time}"))
    return rows


def table2_sync_schedules():
    """Table 2: 1F1B-SNO vs 1F1B-SO (the paper's overlap schedule)."""
    rows = []
    M, N, FB, SR, a, w = 16, 4, 1.0, 0.1, 4.0, 10.0
    for name in ("1F1B-SNO", "1F1B-SO"):
        ev = S.SCHEDULES[name](M, N, FB, FB, SR, a, w)
        sim = simulate(name, M, N, FB, FB, SR)
        rows.append((f"table2.{name}.minibatch_time", ev.minibatch_time,
                     f"sim={sim.makespan:.2f}"))
        rows.append((f"table2.{name}.bubble", ev.bubble_fraction,
                     f"feat_mem_stage1={ev.features_memory[0]}"))
    so = S.eval_1f1b_so(M, N, FB, FB, SR, a, w)
    sno = S.eval_1f1b_sno(M, N, FB, FB, SR, a, w)
    rows.append(("table2.SO_speedup_over_SNO",
                 sno.minibatch_time / so.minibatch_time,
                 "paper: SO strictly faster, 2x activation memory"))
    return rows


# GLOO-over-PCIe effective bandwidth (paper uses the GLOO backend; its
# all-reduce achieves a fraction of raw PCIe).
_V100_GLOO = DeviceSpec(
    name="v100_gloo", peak_flops=V100.peak_flops,
    hbm_bandwidth=V100.hbm_bandwidth, memory_capacity=V100.memory_capacity,
    link_bandwidth=3e9, async_capable=False, efficiency=V100.efficiency)


def table3_epoch_time():
    """Table 3: epoch-time speedup over DP for VGG-16 / ResNet-50 / GNMT-8
    on 4- and 8-V100 clusters; DP vs PipeDream vs GPipe vs BaPipe."""
    rows = []
    cases = [("vgg16", profile_vgg16(), 128),
             ("resnet50", profile_resnet50(), 128),
             ("gnmt8", profile_gnmt(8), 256)]
    for name, prof, minibatch in cases:
        for n in (4, 8):
            cl = homogeneous_cluster(_V100_GLOO, n)
            dp_t, _, _ = dp_time_and_memory(prof, cl, minibatch)
            r = explore(prof, cl, minibatch)
            pd_t, _ = pipedream_time(prof, cl, minibatch)
            gp_t, _ = gpipe_time(prof, cl, minibatch, M=8)
            base = f"table3.{name}.{n}v100"
            rows.append((f"{base}.bapipe_speedup", dp_t / r.minibatch_time,
                         f"mode={r.mode} sched={r.schedule} M={r.M}"))
            rows.append((f"{base}.pipedream_speedup", dp_t / pd_t, ""))
            rows.append((f"{base}.gpipe_speedup", dp_t / gp_t, ""))
    return rows


def table4_max_model():
    """Table 4: max trainable GNMT-L per framework on 1..8 V100s (16 GB).

    Memory model (calibrated once against the paper's single-GPU limit and
    held fixed across frameworks): GNMT dims d=1024, seq=50, B=32/GPU;
    training state = 36 B/param (fp32 weights+grads+Adam moments plus
    allocator overhead); LSTM activations ~= 8 gate tensors/step =
    seq*d*2B*8 per sample per layer.

    * DP / PipeDream: whole model per GPU (PipeDream's stage-0 weight
      stash holds N versions of W/N — same total as DP, the paper's point).
    * GPipe: W/N of training state, but activations of the WHOLE
      mini-batch (M micro-batches resident, no recompute).
    * BaPipe (1F1B-SNO): W/N of training state and only (N-i+1) resident
      micro-batches — stage 0 worst.
    """
    CAP = 16e9
    TRAIN_BPP = 36.0
    d, seq, B = 1024, 50, 32
    w_layer = 8.0 * d * d * 2          # params per LSTM layer (in+rec gates)
    act_layer = seq * d * 2.0 * 8      # bytes per sample per layer
    rows = []

    def w_params(L):
        return w_layer * L + d * 32000     # + softmax

    def max_L(mem_fn):
        L = 2
        while L <= 2048 and mem_fn(L) <= CAP:
            L += 2
        return L - 2

    for n in (1, 2, 4, 8):
        minibatch = B * n
        dp_L = max_L(lambda L: TRAIN_BPP * w_params(L) + B * act_layer * L)
        pd_L = dp_L                        # weight stashing: N x (W/N)
        if n == 1:
            gp_L = bp_L = dp_L
        else:
            M = 2 * n                      # paper: M = 2 x stages
            mb_samples = minibatch / M
            gp_L = max_L(lambda L: TRAIN_BPP * w_params(L) / n
                         + minibatch * act_layer * L / n)
            bp_L = max_L(lambda L: TRAIN_BPP * w_params(L) / n
                         + n * mb_samples * act_layer * L / n)
        for name, val in (("dp", dp_L), ("pipedream", pd_L),
                          ("gpipe", gp_L), ("bapipe", bp_L)):
            rows.append((f"table4.{name}.maxL.{n}v100", val,
                         f"params={w_params(val)/1e6:.0f}M"
                         + (f" scaling={val/max(dp_L,1):.2f}x_over_DP"
                            if name == "bapipe" else "")))
    return rows


def table_hetero():
    """Beyond-paper heterogeneous-cost column (the paper's §V skewed
    FPGA-cluster methodology): a 2+2 fast/slow 4-device chain over
    balanced layers at a granularity the partitioner cannot even out, so
    the per-stage costs stay genuinely skewed.  The uniform-scalar
    explorer (legacy bottleneck collapse) and the cost-shaped explorer
    (per-device StageCosts vector) each pick a plan; both picks are
    replayed at the TRUE per-device durations — the cost-shaped zb-auto
    table wins strictly (ISSUE 5 acceptance fixture)."""
    import dataclasses as _dc
    from repro.core import schedplan as SP
    from repro.core.profiler import LayerProfile, NetworkProfile

    rows = []
    prof = NetworkProfile("balanced7", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(7)), unit="sample")
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0)
    slow = _dc.replace(fast, name="slow", peak_flops=50e12)
    cl = heterogeneous_cluster([fast, slow, fast, slow])
    M, N = 8, 4
    r_vec = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                    candidate_Vs=())
    r_sca = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                    candidate_Vs=(), hetero=False)
    costs = r_sca.plan.cost_vector()
    if SP.canonical_name(r_sca.schedule) == "zb-auto":
        Fb, Bb = r_sca.plan.bottleneck_FB()
        table = SP.build_zb_auto(M, N, (Fb, Bb / 2, Bb / 2))
    else:
        # the legacy name keeps its builder kwargs (FBP-AS's doubled
        # warm-up) — don't canonicalise them away
        table = SP.build_schedule(r_sca.schedule, M, N, 1)
    true_scalar = simulate(table, M, N, list(costs.F), list(costs.B_full),
                           0.0, w_frac=list(costs.w_frac)).makespan
    rows.append(("tableH.2fast+2slow.cost_shaped.minibatch_time",
                 r_vec.minibatch_time,
                 f"sched={r_vec.schedule} M={r_vec.M} "
                 f"layers={r_vec.plan.layers_per_stage()}"))
    rows.append(("tableH.2fast+2slow.uniform_scalar.minibatch_time",
                 true_scalar,
                 f"sched={r_sca.schedule} (scalar pick replayed at true "
                 f"per-device durations)"))
    rows.append(("tableH.2fast+2slow.speedup",
                 true_scalar / r_vec.minibatch_time,
                 f"per_device_F={[round(f, 4) for f in costs.F]}"))
    # the paper's own mixed-FPGA cluster, same comparison
    cl = heterogeneous_cluster([VCU129, VCU129, VCU118, VCU118])
    rp = profile_resnet50()
    r_vec = explore(rp, cl, 128, consider_dp=False)
    r_sca = explore(rp, cl, 128, consider_dp=False, hetero=False)
    rows.append(("tableH.2xVCU129+2xVCU118.cost_shaped_vs_scalar_pred",
                 r_sca.minibatch_time / r_vec.minibatch_time,
                 f"vec={r_vec.schedule}@{r_vec.minibatch_time:.4g} "
                 f"scalar={r_sca.schedule}@{r_sca.minibatch_time:.4g} "
                 "(1.0 == the DP balanced the mix away; >1 == the "
                 "bottleneck collapse overestimated)"))
    return rows


def _ddr(dev: DeviceSpec) -> DeviceSpec:
    """DP on FPGA must keep weights in DDR (40 GB/s), not on-chip (paper
    §4.3: 'DP has to store weights in DDR due to the size limits')."""
    import dataclasses as _dc
    return _dc.replace(dev, hbm_bandwidth=40e9, memory_capacity=64e9)


def table6_fpga():
    """Table 6: ResNet-50 batch-time speedup over DP on FPGA clusters
    (4xVCU118 / 2+2 / 4xVCU129); BaPipe auto-chooses an async schedule and
    keeps per-stage weights on-chip, DP streams from DDR."""
    rows = []
    prof = profile_resnet50()
    clusters = {
        "4xVCU118": [VCU118] * 4,
        "2xVCU129+2xVCU118": [VCU129, VCU129, VCU118, VCU118],
        "4xVCU129": [VCU129] * 4,
    }
    for name, devs in clusters.items():
        dp_t, _, _ = dp_time_and_memory(
            prof, heterogeneous_cluster([_ddr(d) for d in devs]), 128)
        r = explore(prof, heterogeneous_cluster(devs), 128,
                    consider_dp=False)
        rows.append((f"table6.{name}.speedup_over_dp",
                     dp_t / r.minibatch_time,
                     f"sched={r.schedule} M={r.M}"))
    return rows


ALL_TABLES = [table1_async_schedules, table_interleaved,
              table2_sync_schedules, table3_epoch_time, table4_max_model,
              table6_fpga, table_hetero]
