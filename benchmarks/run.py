"""Benchmark harness: one function per paper table plus the roofline
summary from the dry-run artifacts.  Prints ``name,value,derived`` CSV.

``--dry-run`` emits the analytic tables only (no roofline artifacts
needed) — the ``make tables`` smoke target.
"""
from __future__ import annotations

import sys


def main(argv=None) -> None:
    from benchmarks.paper_tables import ALL_TABLES

    argv = sys.argv[1:] if argv is None else argv
    dry = "--dry-run" in argv

    print("name,value,derived")
    for fn in ALL_TABLES:
        for name, value, derived in fn():
            print(f"{name},{value:.4g},{derived}" if isinstance(value, float)
                  else f"{name},{value},{derived}")
    if dry:
        return
    from benchmarks import roofline
    rows = roofline.load_all()
    if rows:
        for name, val, extra in roofline.rows_csv(rows):
            print(f"{name},{val},{extra}")
        picks = roofline.pick_hillclimb_pairs(rows)
        for k, r in picks.items():
            print(f"hillclimb.{k},{r['arch']}/{r['shape']},"
                  f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}")
    else:
        print("roofline,skipped,run `python -m repro.launch.dryrun` first")


if __name__ == "__main__":
    main()
