# Convenience targets; CI runs the same commands.
PY ?= python
export PYTHONPATH := src:.

.PHONY: test smoke tables

test:
	$(PY) -m pytest -x -q

# fast analytic check: simulator vs closed forms (no jax device work)
smoke:
	$(PY) -m pytest -q tests/test_simulator_vs_closed_form.py

# paper tables, analytic only (no roofline dry-run artifacts required)
tables:
	$(PY) -m benchmarks.run --dry-run
