"""End-to-end pipeline-parallel training driver.

CPU quickstart (8 virtual devices, reduced model):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train \\
        --arch llama3.2-1b --reduced --data 2 --stages 2 --tensor 2 \\
        --steps 200 --batch 8 --seq 128

On real hardware drop ``--reduced`` and size the mesh to the pod
(``--data 16 --stages 8 --tensor 2`` etc.).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import (checkpoint_meta, layout_dict,
                              reshard_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.data.synthetic import shard_batch
from repro.optim import AdamW, warmup_cosine
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--stages", type=int, default=0)
    ap.add_argument("--tensor", type=int, default=0)
    ap.add_argument("--virtual", type=int, default=0,
                    help="1F1B-I virtual stages (chunks) per device; "
                         "needs --microbatches >= stages")
    ap.add_argument("--schedule", default="",
                    help="pipeline op order: auto | gpipe | 1f1b | dapple"
                         " | zb-h1 | zb-h2 | zb-auto | 1f1b-interleaved |"
                         " 1f1b-interleaved-memlean (memlean needs"
                         " --microbatches %% stages == 0); backward order"
                         " is executed as first-class ticks")
    ap.add_argument("--runtime", default="", choices=("", "ticks", "stream"),
                    help="training executor: ticks (synchronous tick grid,"
                         " both rings shift every tick) | stream (compiled"
                         " instruction streams — ring collectives only at"
                         " scheduled SEND slots, so W/idle slots overlap"
                         " compute with no barrier)")
    ap.add_argument("--grad-sync", default="",
                    choices=("", "auto", "end", "overlap", "2bw"),
                    help="data-parallel gradient sync placement: end"
                         " (trailing full-pytree psum) | overlap (AR"
                         " bucket ops scheduled into the pipeline drain,"
                         " executed inside the tick scan; needs"
                         " --runtime stream) | 2bw (PipeDream-2BW"
                         " double-buffered weights: step k's grads apply"
                         " at step k+1, so the AR never blocks the next"
                         " step's warmup — sync-free steady state,"
                         " stale-by-one) | auto (overlap iff the"
                         " stream runtime is active)")
    ap.add_argument("--ar-groups", type=int, default=1,
                    help="with overlapped grad sync: split each per-"
                         "(device, chunk) AR bucket into N per-layer-"
                         "group buckets released as each group's W"
                         " retires mid-drain (earlier release, lower"
                         " exposed sync; layers per chunk must divide"
                         " evenly)")
    ap.add_argument("--mem-limit", type=int, default=0,
                    help="zb-auto only: peak-live cap (resident micro-batch"
                         " residuals per device). 0 = unbounded, the fully"
                         " bubble-free order at an M-deep residual stash;"
                         " stages (=1F1B window) reproduces zb-h1,"
                         " ~2*stages reproduces zb-h2")
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="stage")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt", default="",
                    help="checkpoint base path; saves the FULL training "
                         "state {params, opt} + step + stage layout")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="with --ckpt: also save every N steps (the "
                         "survive loop; 0 = only at exit)")
    ap.add_argument("--resume", default="",
                    help="checkpoint base path to resume from; a layout "
                         "mismatch (different stages/virtual) reshards "
                         "the checkpoint on the host first "
                         "(repro.checkpoint.reshard)")
    ap.add_argument("--die-at", type=int, default=0,
                    help="fault injection: exit(17) after completing N "
                         "steps, WITHOUT saving — resume restarts from "
                         "the last --ckpt-every boundary")
    ap.add_argument("--losses-out", default="",
                    help="write {start, losses} JSON here (harness "
                         "cross-process loss comparison)")
    ap.add_argument("--drift-every", type=int, default=0,
                    help="update the drift monitor every N steps from "
                         "live block-proxy timings (0 = off)")
    ap.add_argument("--drift-threshold", type=float, default=0.25,
                    help="max per-stage relative share error before the "
                         "monitor triggers a replan")
    ap.add_argument("--drift-inject", default="",
                    help="comma-separated per-stage slowdown factors "
                         "multiplied into the measured vector "
                         "(deterministic drift for tests/CI)")
    ap.add_argument("--replan-budget", type=float, default=5.0,
                    help="seconds the drift-triggered re-search may "
                         "spend before returning the incumbent")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--auto-plan", action="store_true",
                    help="let the BaPipe explorer pick stages/tensor/M")
    ap.add_argument("--auto-plan3d", action="store_true",
                    help="search the per-stage (DP, TP) degree space over "
                         "a homogeneous device pool (--pool chips) and "
                         "adopt the best UNIFORM executable plan; non-"
                         "uniform winners are reported analytically")
    ap.add_argument("--pool", type=int, default=0,
                    help="device-pool size for --auto-plan3d "
                         "(default: jax.device_count())")
    ap.add_argument("--cluster", default="",
                    help="comma-separated per-stage device names for "
                         "--auto-plan on a heterogeneous pod "
                         "(tpu_v5e|v100|vcu118|vcu129); fixes the stage "
                         "count to the list length and ranks candidates "
                         "by the scheduled heterogeneous makespan of the "
                         "per-device cost vector")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=args.layers or 4,
                          d_model=args.d_model or 256, seq=args.seq)
    if args.stages:
        cfg = dataclasses.replace(cfg, stages=args.stages)
    if args.tensor:
        cfg = dataclasses.replace(cfg, tensor=args.tensor)
    if args.virtual:
        cfg = dataclasses.replace(cfg, virtual=args.virtual)
    if args.schedule:
        cfg = dataclasses.replace(cfg, schedule=args.schedule)
    if args.runtime:
        cfg = dataclasses.replace(cfg, runtime=args.runtime)
    if args.mem_limit:
        if not args.auto_plan:
            from repro.core.schedplan import canonical_name
            sched = cfg.schedule if cfg.schedule not in ("auto", "") \
                else "1f1b"
            if canonical_name(sched) != "zb-auto":
                ap.error(f"--mem-limit only applies to --schedule zb-auto "
                         f"(or --auto-plan); got --schedule {sched}")
        cfg = dataclasses.replace(cfg, mem_limit=args.mem_limit)
    if args.cluster and not args.auto_plan:
        ap.error("--cluster only applies to --auto-plan")
    if args.auto_plan and args.auto_plan3d:
        ap.error("--auto-plan and --auto-plan3d are mutually exclusive")
    if args.auto_plan3d:
        from repro.core.autoplan import auto_plan3d
        plan_ = auto_plan3d(cfg, global_batch=args.batch, seq_len=args.seq,
                            n_devices=args.pool or jax.device_count(),
                            mem_limit=args.mem_limit or None)
        cfg = plan_.apply(cfg)
        args.data = plan_.data_axis
        args.microbatches = plan_.n_microbatches
        widths = "x".join(str(w) for w in plan_.stage_widths)
        print(f"auto-plan3d: stages={plan_.stages} data={plan_.data_axis} "
              f"tensor={plan_.tensor} M={plan_.n_microbatches} "
              f"sched={plan_.schedule} widths={widths} "
              f"(predicted {plan_.predicted_step_time*1e3:.2f} ms/step, "
              f"{plan_.predicted_speedup_over_dp:.2f}x over best "
              f"pipeline-only)")
    if args.auto_plan:
        from repro.core.autoplan import auto_plan
        devices = None
        if args.cluster:
            from repro.core.hardware import TPU_V5E, V100, VCU118, VCU129
            catalogue = {d.name: d for d in (TPU_V5E, V100, VCU118, VCU129)}
            try:
                devices = [catalogue[s.strip()]
                           for s in args.cluster.split(",")]
            except KeyError as e:
                ap.error(f"unknown device {e.args[0]!r} in --cluster "
                         f"(know: {', '.join(sorted(catalogue))})")
        plan_ = auto_plan(cfg, global_batch=args.batch, seq_len=args.seq,
                          model_axis=cfg.stages * cfg.tensor,
                          data_axis=args.data, devices=devices,
                          mem_limit=args.mem_limit or None)
        cfg = plan_.apply(cfg)
        args.microbatches = plan_.n_microbatches
        print(f"auto-plan: stages={plan_.stages} tensor={plan_.tensor} "
              f"M={plan_.n_microbatches} sched={plan_.schedule} "
              f"V={plan_.virtual} "
              f"(predicted {plan_.predicted_step_time*1e3:.2f} ms/step)")
    need = args.data * cfg.stages * cfg.tensor
    assert need <= jax.device_count(), \
        f"mesh needs {need} devices, have {jax.device_count()} " \
        "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((args.data, cfg.stages, cfg.tensor),
                     ("data", "stage", "tensor"))
    plan = ST.plan_stages(cfg)
    print(f"arch={cfg.arch_id} layers={cfg.n_layers} d={cfg.d_model} "
          f"mesh=data{args.data} x stage{cfg.stages} x tensor{cfg.tensor}"
          + (f" x virtual{cfg.virtual}" if cfg.virtual > 1 else ""))

    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(args.seed), plan)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")
    opt = AdamW(lr=warmup_cosine(args.lr, 20, args.steps), weight_decay=0.01)
    opt_state = opt.init(params)
    pcfg = RT.PipelineConfig(n_microbatches=args.microbatches,
                             schedule=cfg.schedule, remat=args.remat,
                             mem_limit=cfg.mem_limit, runtime=cfg.runtime,
                             grad_sync=args.grad_sync or "auto",
                             ar_groups=args.ar_groups)
    step_fn, specs = RT.make_train_step(cfg, mesh, plan, pcfg, optimizer=opt)
    if args.grad_sync == "2bw":
        # the wrapped state (inner/pending/primed) is what gets stepped,
        # checkpointed, and resumed
        opt_state = RT.init_2bw_state(opt_state, params)

    layout = layout_dict(plan, cfg.n_layers)

    def _save(path, step_done, params, opt_state):
        save_checkpoint(path, dict(params=params, opt=opt_state),
                        step=step_done,
                        extra=dict(layout=layout, arch=cfg.arch_id))

    start_step = 0
    if args.resume:
        meta = checkpoint_meta(args.resume)
        src_layout = (meta.get("extra") or {}).get("layout")
        resume_path = args.resume
        if src_layout and any(
                src_layout.get(k) != layout[k]
                for k in ("stages", "virtual", "layers_per_stage",
                          "n_layers_padded")):
            resume_path = f"{args.resume}.to{plan.n_stages}v{plan.virtual}"
            reshard_checkpoint(args.resume, resume_path, plan)
            print(f"resharded checkpoint: stages"
                  f"{src_layout['stages']} x virtual"
                  f"{src_layout.get('virtual', 1)} -> stages"
                  f"{plan.n_stages} x virtual{plan.virtual}")
        p_sh, o_sh = RT.state_shardings(mesh, specs, opt_state)
        state = restore_checkpoint(resume_path,
                                   dict(params=params, opt=opt_state),
                                   shardings=dict(params=p_sh, opt=o_sh))
        params, opt_state = state["params"], state["opt"]
        start_step = int(checkpoint_meta(resume_path)["step"])
        print(f"resumed from {resume_path} at step {start_step}")

    monitor, inject, replanned = None, None, False
    if args.drift_every:
        from repro.core import profiler as PF
        planned = PF.planned_stage_costs(cfg, plan, seq=args.seq)
        monitor = PF.DriftMonitor(planned=tuple(planned),
                                  threshold=args.drift_threshold)
        if args.drift_inject:
            inject = [float(x) for x in args.drift_inject.split(",")]
            if len(inject) != plan.n_stages:
                ap.error(f"--drift-inject needs {plan.n_stages} factors, "
                         f"got {len(inject)}")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)
    bspec = dict(tokens=NamedSharding(mesh, P(("data",), None)),
                 labels=NamedSharding(mesh, P(("data",), None)))
    def _dump_losses():
        if args.losses_out:
            import json
            with open(args.losses_out, "w") as f:
                json.dump(dict(start=start_step, losses=losses), f)

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = shard_batch(data.batch(step), bspec)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((args.batch, 64, cfg.d_model))
        if cfg.family == "vlm":
            batch["pos3"] = jnp.broadcast_to(
                jnp.arange(args.seq)[None, None],
                (3, args.batch, args.seq)).astype(jnp.int32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tput = (step + 1 - start_step) * args.batch * args.seq / dt
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"({tput:.0f} tok/s)", flush=True)
        if monitor is not None and not replanned \
                and (step + 1) % args.drift_every == 0:
            from repro.core import profiler as PF
            measured = PF.measure_stage_times(cfg, plan,
                                              seq=min(args.seq, 64), iters=2)
            if measured is None:
                # proxy timing unavailable: no live signal, no drift
                measured = list(monitor.planned)
            if inject:
                measured = [m * f for m, f in zip(measured, inject)]
            drift = monitor.update(measured)
            if monitor.should_replan():
                from repro.core.autoplan import AutoPlan, replan
                incumbent = AutoPlan(
                    stages=cfg.stages, tensor=cfg.tensor,
                    n_microbatches=args.microbatches,
                    schedule=cfg.schedule,
                    predicted_step_time=float("inf"),
                    predicted_speedup_over_dp=1.0, virtual=cfg.virtual,
                    mem_limit=cfg.mem_limit, data_axis=args.data)
                new = replan(cfg, incumbent, budget_s=args.replan_budget,
                             global_batch=args.batch, seq_len=args.seq,
                             slowdown=list(monitor.slowdown()))
                if new is incumbent:
                    print(f"drift {drift:.2f} at step {step}: replan kept "
                          f"the incumbent plan", flush=True)
                else:
                    print(f"drift {drift:.2f} at step {step}: replan -> "
                          f"stages={new.stages} tensor={new.tensor} "
                          f"M={new.n_microbatches} sched={new.schedule} "
                          f"V={new.virtual} (predicted "
                          f"{new.predicted_step_time * 1e3:.2f} ms/step); "
                          f"restart with --resume to adopt", flush=True)
                replanned = True
        if args.ckpt and args.ckpt_every \
                and (step + 1) % args.ckpt_every == 0:
            _save(args.ckpt, step + 1, params, opt_state)
        if args.die_at and step + 1 >= args.die_at:
            _dump_losses()
            print(f"fault injection: dying after step {step + 1}",
                  flush=True)
            raise SystemExit(17)
    n = min(10, max(1, len(losses)))
    print(f"first-{n} mean loss {sum(losses[:n])/n:.4f} -> "
          f"last-{n} mean loss {sum(losses[-n:])/n:.4f}")
    _dump_losses()
    if args.ckpt:
        _save(args.ckpt, args.steps, params, opt_state)
        print(f"saved checkpoint to {args.ckpt}.npz")
    return losses


if __name__ == "__main__":
    main()
