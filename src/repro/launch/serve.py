"""Pipelined serving driver: batched prefill + greedy decode.

CPU quickstart:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve \\
        --arch llama3.2-1b --reduced --data 2 --stages 2 --tensor 2 \\
        --batch 8 --prompt-len 32 --gen 16

``--virtual V`` (V > 1) runs the *prefill* phase on an interleaved
1F1B-I plan — prefill is throughput-bound, so the V-times-smaller flush
bubble pays — then unstacks the V-chunk parameters and restacks them
contiguously for the latency-bound decode loop, whose plan stays V=1.
The prefill cache is written chunk-stacked [S, V, Lc, ...] and is
re-folded to the contiguous [S, Lps, ...] decode layout between phases.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--stages", type=int, default=0)
    ap.add_argument("--tensor", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--virtual", type=int, default=0,
                    help="interleave the PREFILL over V chunks/device "
                         "(decode always runs the contiguous V=1 plan)")
    ap.add_argument("--schedule", default="auto",
                    help="prefill op order (schedplan name); memlean needs "
                         "--microbatches %% stages == 0")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=256)
    if args.stages:
        cfg = dataclasses.replace(cfg, stages=args.stages)
    if args.tensor:
        cfg = dataclasses.replace(cfg, tensor=args.tensor)
    if args.virtual:
        cfg = dataclasses.replace(cfg, virtual=args.virtual)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((args.data, cfg.stages, cfg.tensor),
                     ("data", "stage", "tensor"))
    # decode always runs the contiguous plan; prefill may interleave
    plan = ST.plan_stages(cfg, virtual=1)
    plan_p = ST.plan_stages(cfg) if cfg.virtual > 1 else plan
    params_p = ST.init_stacked_params(cfg, jax.random.PRNGKey(args.seed),
                                      plan_p)
    params = ST.restack_params(params_p, plan_p, plan, cfg.n_layers) \
        if cfg.virtual > 1 else params_p
    max_len = args.prompt_len + args.gen
    pcfg = RT.PipelineConfig(n_microbatches=args.microbatches,
                             schedule=args.schedule)
    pcfg1 = RT.PipelineConfig(n_microbatches=args.microbatches)

    prefill, _, cspecs_p, _ = RT.make_serve_step(
        cfg, mesh, plan_p, pcfg, max_len=max_len, global_batch=args.batch,
        q_len=args.prompt_len)
    decode, _, cspecs, _ = RT.make_serve_step(
        cfg, mesh, plan, pcfg1, max_len=max_len, global_batch=args.batch,
        q_len=1)
    cache = jax.jit(
        lambda: RT.init_pipeline_cache(cfg, plan_p, args.batch, max_len),
        out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   cspecs_p))()

    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    logits, cache = prefill(params_p, cache, dict(tokens=prompt))
    logits.block_until_ready()
    t_prefill = time.time() - t0
    if cfg.virtual > 1:
        # re-fold the chunk-stacked [S, V, Lc, ...] prefill cache into the
        # contiguous [S, Lps, ...] layout the decode plan scans
        refold = jax.jit(
            lambda c: jax.tree.map(
                lambda a: ST.restack_layers(a, plan_p, plan, cfg.n_layers), c),
            out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                       cspecs))
        cache = refold(cache)
    next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    generated = [np.asarray(next_tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, dict(tokens=next_tok[:, None]))
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        generated.append(np.asarray(next_tok))
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    toks = np.stack(generated, 1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode:  {args.gen - 1} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f}ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample generations (first 3 rows):")
    for row in toks[:3]:
        print("  ", row.tolist())
    return toks


if __name__ == "__main__":
    main()
