"""Pipelined serving driver: batched prefill + greedy decode.

CPU quickstart:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.serve \\
        --arch llama3.2-1b --reduced --data 2 --stages 2 --tensor 2 \\
        --batch 8 --prompt-len 32 --gen 16

``--virtual V`` (V > 1) runs the *prefill* phase on an interleaved
1F1B-I plan — prefill is throughput-bound, so the V-times-smaller flush
bubble pays — then restacks the V-chunk parameters and the chunk-stacked
[S, V, Lc, ...] prefill cache contiguously for the decode loop, whose
plan stays V=1.  The restack runs as ONE jitted call that *donates* the
prefill copies: the contiguous buffers are built in place of the chunked
ones, so the handoff never holds params+cache twice (the old eager
restack had a transient 2x residency spike).

Timing discipline: both jitted steps are AOT-compiled (``.lower(...)
.compile()``) before any timed region and every phase is fenced with
``block_until_ready`` — compile time, prefill throughput, and
steady-state decode throughput are reported separately instead of the
first decode step's compile silently landing inside the decode loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--stages", type=int, default=0)
    ap.add_argument("--tensor", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--virtual", type=int, default=0,
                    help="interleave the PREFILL over V chunks/device "
                         "(decode always runs the contiguous V=1 plan)")
    ap.add_argument("--schedule", default="auto",
                    help="prefill op order (schedplan name); memlean needs "
                         "--microbatches %% stages == 0")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=4, d_model=256)
    if args.stages:
        cfg = dataclasses.replace(cfg, stages=args.stages)
    if args.tensor:
        cfg = dataclasses.replace(cfg, tensor=args.tensor)
    if args.virtual:
        cfg = dataclasses.replace(cfg, virtual=args.virtual)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((args.data, cfg.stages, cfg.tensor),
                     ("data", "stage", "tensor"))
    # decode always runs the contiguous plan; prefill may interleave
    plan = ST.plan_stages(cfg, virtual=1)
    plan_p = ST.plan_stages(cfg) if cfg.virtual > 1 else plan
    params_p = ST.init_stacked_params(cfg, jax.random.PRNGKey(args.seed),
                                      plan_p)
    max_len = args.prompt_len + args.gen
    pcfg = RT.PipelineConfig(n_microbatches=args.microbatches,
                             schedule=args.schedule)
    pcfg1 = RT.PipelineConfig(n_microbatches=args.microbatches)

    prefill, _, cspecs_p, _ = RT.make_serve_step(
        cfg, mesh, plan_p, pcfg, max_len=max_len, global_batch=args.batch,
        q_len=args.prompt_len)
    decode, dspecs, cspecs, _ = RT.make_serve_step(
        cfg, mesh, plan, pcfg1, max_len=max_len, global_batch=args.batch,
        q_len=1)
    cache = jax.jit(
        lambda: RT.init_pipeline_cache(cfg, plan_p, args.batch, max_len),
        out_shardings=jax.tree.map(lambda s: NamedSharding(mesh, s),
                                   cspecs_p))()

    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    tok0 = jnp.zeros((args.batch, 1), jnp.int32)

    # ---- warm-up: compile every phase before any timed region ------------
    t0 = time.perf_counter()
    prefill_x = prefill.lower(params_p, cache, dict(tokens=prompt)).compile()
    restack_x = None
    if cfg.virtual > 1:
        # one donated jitted call re-folds the V-chunked params AND the
        # chunk-stacked [S, V, Lc, ...] prefill cache to the contiguous
        # [S, Lps, ...] decode layout in place of the prefill buffers
        shard = lambda tree: jax.tree.map(
            lambda s: NamedSharding(mesh, s), tree)

        def _restack(p, c):
            p2 = ST.restack_params(p, plan_p, plan, cfg.n_layers)
            c2 = jax.tree.map(
                lambda a: ST.restack_layers(a, plan_p, plan, cfg.n_layers), c)
            return p2, c2

        restack_x = jax.jit(
            _restack, donate_argnums=(0, 1),
            out_shardings=(shard(dspecs), shard(cspecs)))
        params_shapes, cache_shapes = jax.eval_shape(_restack, params_p,
                                                     cache)
        import warnings
        with warnings.catch_warnings():
            # the chunked->contiguous layout change blocks in-place
            # aliasing for the re-folded leaves; those are instead freed
            # by the `del params_p` right after the handoff call
            # (tests/test_serve_sched.py pins both halves)
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            restack_x = restack_x.lower(params_p, cache).compile()
        decode_x = decode.lower(
            jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                params_shapes, dspecs),
            jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
                cache_shapes, cspecs),
            dict(tokens=tok0)).compile()
    else:
        decode_x = decode.lower(params_p, cache, dict(tokens=tok0)).compile()
    t_compile = time.perf_counter() - t0

    # ---- prefill ----------------------------------------------------------
    t0 = time.perf_counter()
    logits, cache = prefill_x(params_p, cache, dict(tokens=prompt))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # ---- prefill -> decode handoff (donating restack) ---------------------
    t0 = time.perf_counter()
    if cfg.virtual > 1:
        params, cache = restack_x(params_p, cache)
        # drop the last reference to the prefill-layout copies: the
        # layout-changing leaves cannot be aliased by the donation, so
        # they stay resident until this name dies
        del params_p
        jax.block_until_ready(params)
    else:
        params = params_p
    t_handoff = time.perf_counter() - t0

    next_tok = jnp.argmax(logits[:, 0, :cfg.vocab], axis=-1).astype(jnp.int32)
    generated = [np.asarray(next_tok)]

    # ---- steady-state decode (everything below is compiled + fenced) ------
    jax.block_until_ready(next_tok)
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode_x(params, cache, dict(tokens=next_tok[:, None]))
        next_tok = jnp.argmax(logits[:, 0, :cfg.vocab],
                              axis=-1).astype(jnp.int32)
        generated.append(np.asarray(next_tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    toks = np.stack(generated, 1)
    pre_toks = args.batch * args.prompt_len
    dec_toks = (args.gen - 1) * args.batch
    print(f"compile: {t_compile*1e3:.1f}ms (excluded from all phases)")
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms "
          f"({pre_toks / max(t_prefill, 1e-9):.0f} tok/s)")
    if cfg.virtual > 1:
        print(f"handoff: V={cfg.virtual} restack (donated) in "
              f"{t_handoff*1e3:.1f}ms")
    print(f"decode:  {args.gen - 1} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f}ms "
          f"({dec_toks / max(t_decode, 1e-9):.0f} tok/s steady-state)")
    print("sample generations (first 3 rows):")
    for row in toks[:3]:
        print("  ", row.tolist())
    return toks


if __name__ == "__main__":
    main()
