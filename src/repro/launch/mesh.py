"""Production meshes.

``make_production_mesh`` is the mandated (pod,) data x model mesh.  BaPipe's
pipeline lives on the *model* axis, so ``make_pipeline_mesh`` reshapes the
same device set into (pod,) data x stage x tensor with
``stages * tensor == 16`` (per-arch factorisation from the config).

Functions, not module constants: importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions: newer jax wants explicit Auto
    axis types for shard_map meshes, older jax has no ``axis_types``."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_pipeline_mesh(*, multi_pod: bool = False, stages: int = 16,
                       tensor: int = 1):
    """Same devices as the production mesh with the model axis split into
    (stage, tensor)."""
    assert stages * tensor == 16, (stages, tensor)
    if multi_pod:
        shape = (2, 16, stages, tensor)
        axes = ("pod", "data", "stage", "tensor")
    else:
        shape = (16, stages, tensor)
        axes = ("data", "stage", "tensor")
    return make_mesh(shape, axes)
