import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, record memory/cost analysis and collective-traffic
bytes for the roofline.

No arrays are ever allocated: parameters, caches and batches are
ShapeDtypeStructs with NamedShardings; ``jit(...).lower(...).compile()``
proves the sharding config is coherent and yields the roofline terms.

Usage:
    python -m repro.launch.dryrun                       # full sweep
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --multi-pod
Outputs one JSON per combo under benchmarks/results/dryrun/.
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, all_arch_ids
from repro.configs.base import INPUT_SHAPES, LONG_CONTEXT_OK, InputShape
from repro.launch.mesh import make_pipeline_mesh
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in (post-SPMD, per-device)
    HLO.  Start-ops only, so async pairs aren't double counted."""
    out = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        op = None
        for c in _COLLECTIVES:
            if rhs.startswith(c + "(") or rhs.startswith(c + "-start("):
                op = c
                break
            # typed prefix: "f32[...] all-reduce(..." — opcode after types
            m = re.match(r"^(?:\([^)]*\)|\S+)\s+([\w-]+)", rhs)
            if m and m.group(1) in (c, c + "-start"):
                op = c
                break
        if op is None:
            continue
        nbytes = 0
        # result types sit between '=' and the opcode in rhs
        head = rhs.split(op)[0]
        for m in shape_re.finditer(head):
            dt, dims = m.group(1), m.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_structs(cfg, plan, mesh, dtype=jnp.bfloat16, stage_axis="stage"):
    shapes = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = ST.param_specs(cfg, shapes, stage_axis=stage_axis,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"],
                           virtual=plan.virtual)
    return jax.tree.map(lambda s, sp: sds(s.shape, s.dtype, mesh, sp),
                        shapes, specs)


def input_specs(cfg, shape: InputShape, mesh, pcfg, *, kind: str):
    """ShapeDtypeStruct stand-ins for every model input."""
    batch_axes = RT._batch_axes(mesh, pcfg)
    n_shards = 1
    for a in batch_axes:
        n_shards *= mesh.shape[a]
    B, T = shape.global_batch, shape.seq_len
    b_sharded = B % n_shards == 0 and B >= n_shards
    baxes = batch_axes if b_sharded else None
    d = dict()
    if kind == "train":
        d["tokens"] = sds((B, T), jnp.int32, mesh, P(baxes, None))
        d["labels"] = sds((B, T), jnp.int32, mesh, P(baxes, None))
        if cfg.family == "vlm":
            d["embeds"] = sds((B, T, cfg.d_model), jnp.bfloat16, mesh,
                              P(baxes, None, None))
            d["pos3"] = sds((3, B, T), jnp.int32, mesh, P(None, baxes, None))
        if cfg.family == "audio":
            d["frames"] = sds((B, 1500, cfg.d_model), jnp.bfloat16, mesh,
                              P(baxes, None, None))
    else:
        q = T if kind == "prefill" else 1
        d["tokens"] = sds((B, q), jnp.int32, mesh, P(baxes, None))
        if cfg.family == "vlm":
            d["pos3"] = sds((3, B, q), jnp.int32, mesh, P(None, baxes, None))
    return d, b_sharded


def pick_microbatches(cfg, shape: InputShape, mesh, pcfg, b_sharded) -> int:
    batch_axes = RT._batch_axes(mesh, pcfg)
    n_shards = 1
    for a in batch_axes:
        n_shards *= mesh.shape[a]
    B_loc = shape.global_batch // n_shards if b_sharded else shape.global_batch
    target = 4 if shape.kind == "train" else RT._n_stages(mesh, pcfg)
    m = min(target, B_loc)
    while B_loc % m:
        m -= 1
    return max(1, m)


def _lower_compile(cfg, shape, mesh, plan, pcfg, b_sharded, ins):
    p_structs = param_structs(cfg, plan, mesh,
                              stage_axis=RT._stage_axes(mesh, pcfg))
    if shape.kind == "train":
        step, _ = RT.make_train_step(cfg, mesh, plan, pcfg,
                                     param_dtype=jnp.bfloat16)
        return step.lower(p_structs, ins).compile()
    q = shape.seq_len if shape.kind == "prefill" else 1
    enc_len = 1500 if cfg.family == "audio" else 0
    step, _, cspecs, cshapes = RT.make_serve_step(
        cfg, mesh, plan, pcfg, batch_sharded=b_sharded,
        param_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
        max_len=shape.seq_len, global_batch=shape.global_batch,
        q_len=q, enc_len=enc_len)
    c_structs = jax.tree.map(
        lambda s_, sp: sds(s_.shape, s_.dtype, mesh, sp), cshapes, cspecs)
    return step.lower(p_structs, c_structs, ins).compile()


def _metrics(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            collective_bytes(compiled.as_text()))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = RESULTS_DIR, remat: str = "stage",
            overrides: dict | None = None, unroll=False) -> dict:
    """``unroll``: False (plain compile proof), True (fully unrolled scans —
    exact loop-aware cost analysis), or "diff" (two-point tick-scan
    differencing for programs too big to fully unroll: cost_analysis counts
    ``u + ticks%u`` copies of a scan body at unroll=u, so two lowerings
    solve for base + per-tick cost exactly)."""
    if unroll:
        from repro.models import layers as _lyr
        _lyr.UNROLL_SCANS = True
        out_dir = out_dir.replace("dryrun", "dryrun_unroll") \
            if out_dir == RESULTS_DIR else out_dir
    cfg = get_config(arch)
    force_M = force_remat = None
    gate = pod_stage = False
    if overrides:
        overrides = dict(overrides)
        force_M = overrides.pop("M", None)
        force_remat = overrides.pop("remat", None)
        gate = bool(overrides.pop("gate", False))
        pod_stage = bool(overrides.pop("pod_stage", False))
        moe_over = {k[4:]: v for k, v in overrides.items() if k.startswith("moe.")}
        plain = {k: v for k, v in overrides.items() if not k.startswith("moe.")}
        if moe_over and cfg.moe is not None:
            plain["moe"] = dataclasses.replace(cfg.moe, **moe_over)
        cfg = dataclasses.replace(cfg, **plain)
        overrides = dict(overrides, **({"M": force_M} if force_M else {}),
                         **({"remat": force_remat} if force_remat else {}))
    if force_remat:
        remat = force_remat
    shape = INPUT_SHAPES[shape_name]
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               stages=cfg.stages, tensor=cfg.tensor, remat=remat,
               status="ok")
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK \
            and not (overrides or {}).get("window"):
        rec["status"] = "skip"
        rec["reason"] = ("pure full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §5)")
        return rec
    t0 = time.time()
    try:
        mesh = make_pipeline_mesh(multi_pod=multi_pod, stages=cfg.stages,
                                  tensor=cfg.tensor)
        depth = cfg.stages * (2 if (pod_stage and multi_pod) else 1)
        plan = ST.plan_stages(cfg, n_stages=depth)
        pcfg0 = RT.PipelineConfig(pod_role="stage" if pod_stage else "data")
        ins, b_sharded = input_specs(cfg, shape, mesh, pcfg0,
                                     kind=shape.kind)
        M_ = force_M or pick_microbatches(cfg, shape, mesh, pcfg0, b_sharded)
        rec["n_microbatches"] = M_
        S_total = RT._n_stages(mesh, RT.PipelineConfig())
        ticks = M_ + S_total - 1
        rec["gated"] = gate
        pod_role = "stage" if pod_stage else "data"
        if unroll == "diff":
            # two-point differencing on the tick scan; inner scans unrolled
            f, b, c = [], [], []
            for u in (1, 2):
                pcfg = RT.PipelineConfig(n_microbatches=M_, remat=remat,
                                         tick_unroll=u, gate_ticks=gate,
                                         pod_role=pod_role)
                compiled = _lower_compile(cfg, shape, mesh, plan, pcfg,
                                          b_sharded, ins)
                fi, bi, ci = _metrics(compiled)
                f.append(fi); b.append(bi); c.append(ci)
            bodies = [1, 2 + (ticks % 2 if ticks > 2 else 0)]
            if ticks <= 2:
                bodies[1] = ticks
            span = max(1, bodies[1] - bodies[0])

            def reconstruct(v1, v2):
                body = (v2 - v1) / span
                return max(v1, v1 - body + ticks * body)
            rec["cost"] = dict(
                flops=reconstruct(f[0], f[1]),
                **{"bytes accessed": reconstruct(b[0], b[1])})
            rec["collectives"] = {
                k: reconstruct(c[0][k], c[1][k]) for k in c[0]}
            rec["unroll_method"] = "tick-diff"
            mem = compiled.memory_analysis()
        else:
            pcfg = RT.PipelineConfig(n_microbatches=M_, remat=remat,
                                     unroll=bool(unroll), gate_ticks=gate,
                                     pod_role=pod_role)
            compiled = _lower_compile(cfg, shape, mesh, plan, pcfg,
                                      b_sharded, ins)
            fi, bi, ci = _metrics(compiled)
            rec["cost"] = dict(flops=fi, **{"bytes accessed": bi})
            rec["collectives"] = ci
            if unroll:
                rec["unroll_method"] = "full"
            mem = compiled.memory_analysis()
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=getattr(mem, "peak_memory_in_bytes", None),
        )
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["compile_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{rec['mesh'].replace('x', '-')}"
    if overrides:
        extra = dict(overrides)
        if force_M: extra["M"] = force_M
        if force_remat: extra["remat"] = force_remat
        if gate: extra["gate"] = 1
        if pod_stage: extra["pod_stage"] = 1
        tag += "_" + "_".join(f"{k}={v}" for k, v in sorted(extra.items()))
        rec["overrides"] = {k: str(v) for k, v in extra.items()}
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="stage")
    ap.add_argument("--unroll", default="", choices=["", "full", "diff"],
                    help="loop-aware cost accounting: 'full' unrolls every "
                         "scan; 'diff' uses two-point tick differencing")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else all_arch_ids()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_fail = n_skip = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                u = {"": False, "full": True, "diff": "diff"}[args.unroll]
                rec = run_one(arch, shape, multi_pod, remat=args.remat,
                              unroll=u)
                n_ok += rec["status"] == "ok"
                n_fail += rec["status"] == "fail"
                n_skip += rec["status"] == "skip"
                msg = rec["status"]
                if rec["status"] == "ok":
                    fl = rec["cost"].get("flops", 0)
                    msg += (f" {rec['compile_s']}s flops/dev={fl:.3g} "
                            f"coll={rec['collectives']['total']:.3g}B "
                            f"M={rec['n_microbatches']}")
                elif rec["status"] == "fail":
                    msg += " " + rec["error"][:160]
                print(f"[{rec['mesh']}] {arch:22s} {shape:12s} {msg}",
                      flush=True)
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")


if __name__ == "__main__":
    main()
