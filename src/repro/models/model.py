"""Model assembly: ArchConfig -> init / forward / decode, single-device or
inside the shard_map pipeline (stage.py slices the stacked layer params).

Layer parameters are stacked on a leading ``[L, ...]`` axis and scanned —
this is what lets the pipeline shard contiguous layer ranges over stages and
keeps compiled HLO size O(1) in depth.  Per-layer heterogeneity (gemma3
local/global, MoE first-k-dense, whisper enc/dec) is expressed with
per-layer metadata arrays consumed by the scanned block body.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = dict


# ---------------------------------------------------------------------------
# Per-layer static metadata (scanned alongside params).
# ---------------------------------------------------------------------------

def layer_meta(cfg: ArchConfig) -> dict:
    n = cfg.n_layers
    is_global = jnp.array([cfg.is_global_layer(i) for i in range(n)])
    theta = jnp.where(is_global,
                      cfg.rope_theta_global or cfg.rope_theta,
                      cfg.rope_theta).astype(jnp.float32)
    is_decoder = jnp.array([i >= cfg.n_enc_layers for i in range(n)]) \
        if cfg.n_enc_layers else jnp.ones((n,), bool)
    is_moe = jnp.array([cfg.moe is not None and i >= cfg.moe.first_k_dense
                        for i in range(n)])
    return dict(is_global=is_global, rope_theta=theta,
                is_decoder=is_decoder, is_moe=is_moe)


# ---------------------------------------------------------------------------
# Single-layer init / apply (family dispatch).
# ---------------------------------------------------------------------------

def init_block(cfg: ArchConfig, key: jax.Array, tp: int, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {"ln1": L.init_rms_norm(d, dtype)}
    if cfg.family == "ssm":
        p["ssm"] = L.init_ssm(ks[0], cfg, tp, dtype)
        return p
    # attention
    if cfg.attn_kind == "mla":
        p["attn"] = L.init_mla(ks[0], cfg, tp, dtype)
    else:
        p["attn"] = L.init_gqa(ks[0], cfg, tp, dtype)
    if cfg.family == "hybrid":
        p["ssm"] = L.init_ssm(ks[1], cfg, tp, dtype)
    if cfg.n_enc_layers:
        p["ln_x"] = L.init_rms_norm(d, dtype)
        p["xattn"] = L.init_cross(ks[2], cfg, tp, dtype)
    p["ln2"] = L.init_rms_norm(d, dtype)
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[3], cfg, tp, dtype)
        if cfg.moe.first_k_dense > 0:
            p["mlp"] = L.init_mlp(ks[4], d, cfg.d_ff, tp, cfg.n_layers, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[4], d, cfg.d_ff, tp, cfg.n_layers, dtype)
    return p


def block_apply(cfg: ArchConfig, p: Params, x, meta_l: dict, *,
                pos, pos3=None, enc=None, cache_l=None,
                tp_axis=None, tp_index=None,
                dp_axis=None, dp_index=None, n_dp=1):
    """Apply one block.  Returns (x', cache_l', aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache_l

    if cfg.family == "audio":
        return _whisper_block(cfg, p, x, meta_l, pos=pos, cache_l=cache_l,
                              tp_axis=tp_axis)

    if cfg.family == "ssm":
        # SSM params are replicated over tensor (never sharded): no psum
        h, new_ssm = L.ssm_block(p["ssm"], L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, cache=None if cache_l is None else cache_l["ssm"],
                                 tp_axis=None)
        x = x + h
        if cache_l is not None:
            new_cache = dict(cache_l, ssm=new_ssm)
        return x, new_cache, aux

    # --- attention (+ parallel SSM for hybrid) -----------------------------
    xin = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        h_attn, new_kv = L.mla_attention(
            p["attn"], xin, cfg, pos=pos,
            cache=None if cache_l is None else cache_l["kv"], tp_axis=tp_axis)
    else:
        h_attn, new_kv = L.gqa_attention(
            p["attn"], xin, cfg, pos=pos, is_global=meta_l["is_global"],
            rope_theta=meta_l["rope_theta"],
            cache=None if cache_l is None else cache_l["kv"],
            tp_axis=tp_axis, tp_index=tp_index, pos3=pos3)
    if cfg.family == "hybrid":
        h_ssm, new_ssm = L.ssm_block(
            p["ssm"], xin, cfg,
            cache=None if cache_l is None else cache_l["ssm"], tp_axis=None)
        h = 0.5 * (h_attn + h_ssm)          # Hymba: parallel head fusion
    else:
        h, new_ssm = h_attn, None
    x = x + h
    # --- FFN ----------------------------------------------------------------
    xin2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        y_moe, aux = L.moe_block(p["moe"], xin2, cfg, cfg.act,
                                 tp_axis=tp_axis, tp_index=tp_index,
                                 dp_axis=dp_axis, dp_index=dp_index,
                                 n_dp=n_dp)
        if cfg.moe.first_k_dense > 0:
            y_dense = L.mlp(p["mlp"], xin2, cfg.act, tp_axis)
            y = jnp.where(meta_l["is_moe"], y_moe, y_dense)
            aux = jnp.where(meta_l["is_moe"], aux, 0.0)
        else:
            y = y_moe
    else:
        y = L.mlp(p["mlp"], xin2, cfg.act, tp_axis)
    x = x + y
    if cache_l is not None:
        new_cache = dict(cache_l)
        if new_kv is not None:
            new_cache["kv"] = new_kv
        if new_ssm is not None:
            new_cache["ssm"] = new_ssm
    return x, new_cache, aux


def _whisper_block(cfg: ArchConfig, p: Params, x, meta_l, *, pos, cache_l,
                   tp_axis):
    """Whisper enc-dec block.  ``x`` is a dict(h_enc, h_dec); encoder layers
    transform h_enc, decoder layers transform h_dec (cross-attending h_enc).
    lax.cond keeps only one path live per layer at runtime."""
    aux = jnp.zeros((), jnp.float32)
    h_enc, h_dec = x["h_enc"], x["h_dec"]
    is_dec = meta_l["is_decoder"]

    if cache_l is not None:
        # decode: only decoder layers do work; encoder layers are identity
        # (their is_decoder flag is False only in the stacked prefix).
        def dec_path(h):
            xin = L.rms_norm(h, p["ln1"], cfg.norm_eps)
            a, new_kv = L.gqa_attention(p["attn"], xin, cfg, pos=pos,
                                        is_global=jnp.array(True),
                                        rope_theta=meta_l["rope_theta"],
                                        cache=cache_l["kv"], tp_axis=tp_axis)
            h = h + a
            xq = L.rms_norm(h, p["ln_x"], cfg.norm_eps)
            hd = cfg.resolved_head_dim
            nh_l = p["xattn"]["wq"].shape[1] // hd
            B, T, _ = xq.shape
            q = (xq @ p["xattn"]["wq"]).reshape(B, T, nh_l, hd)
            o = L.attend(q, cache_l["xk"], cache_l["xv"],
                         scale=1.0 / math.sqrt(hd), causal=False)
            o = o.reshape(B, T, nh_l * hd) @ p["xattn"]["wo"]
            h = h + L._maybe_psum(o, tp_axis)
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"], cfg.norm_eps),
                          cfg.act, tp_axis)
            return h, new_kv

        h_new, new_kv = dec_path(h_dec)
        gate = is_dec.astype(h_dec.dtype)
        h_dec = gate * h_new + (1 - gate) * h_dec
        new_cache = dict(cache_l, kv=jax.tree.map(
            lambda a, b: jnp.where(is_dec, a, b) if a.shape == b.shape else a,
            new_kv, cache_l["kv"]))
        return dict(h_enc=h_enc, h_dec=h_dec), new_cache, aux

    def enc_path(args):
        h_enc, h_dec = args
        xin = L.rms_norm(h_enc, p["ln1"], cfg.norm_eps)
        B, S, _ = xin.shape
        hd = cfg.resolved_head_dim
        nh_l = p["attn"]["wq"].shape[1] // hd
        q = (xin @ p["attn"]["wq"]).reshape(B, S, nh_l, hd)
        k = (xin @ p["attn"]["wk"]).reshape(B, S, -1, hd)
        v = (xin @ p["attn"]["wv"]).reshape(B, S, -1, hd)
        o = L.attend(q, k, v, scale=1.0 / math.sqrt(hd), causal=False)
        o = o.reshape(B, S, nh_l * hd) @ p["attn"]["wo"]
        h_enc = h_enc + L._maybe_psum(o, tp_axis)
        h_enc = h_enc + L.mlp(p["mlp"], L.rms_norm(h_enc, p["ln2"], cfg.norm_eps),
                              cfg.act, tp_axis)
        return h_enc, h_dec

    def dec_path(args):
        h_enc, h_dec = args
        xin = L.rms_norm(h_dec, p["ln1"], cfg.norm_eps)
        a, _ = L.gqa_attention(p["attn"], xin, cfg, pos=pos,
                               is_global=jnp.array(True),
                               rope_theta=meta_l["rope_theta"], tp_axis=tp_axis)
        h_dec = h_dec + a
        xq = L.rms_norm(h_dec, p["ln_x"], cfg.norm_eps)
        h_dec = h_dec + L.cross_attention(p["xattn"], xq, h_enc, cfg, tp_axis)
        h_dec = h_dec + L.mlp(p["mlp"], L.rms_norm(h_dec, p["ln2"], cfg.norm_eps),
                              cfg.act, tp_axis)
        return h_enc, h_dec

    h_enc, h_dec = lax.cond(is_dec, dec_path, enc_path, (h_enc, h_dec))
    return dict(h_enc=h_enc, h_dec=h_dec), None, aux


# ---------------------------------------------------------------------------
# Whole-model init.
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key: jax.Array, *, tp: int = 1,
                dtype=jnp.float32) -> Params:
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    vl = cfg.padded_vocab(tp) // tp if tp > 1 else cfg.vocab
    embed = jax.random.normal(k_emb, (vl, cfg.d_model), dtype) \
        / math.sqrt(cfg.d_model)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_block(cfg, k, tp, dtype))(layer_keys)
    p = dict(embed=embed, layers=stacked,
             final_norm=L.init_rms_norm(cfg.d_model, dtype))
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k_out, (vl, cfg.d_model), dtype) \
            / math.sqrt(cfg.d_model)
    return p


def param_count(p: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(p))


# ---------------------------------------------------------------------------
# Embedding / head with optional vocab sharding over the tensor axis.
# ---------------------------------------------------------------------------

def sinusoid_pos(pos: jax.Array, d: int, dtype) -> jax.Array:
    """[B,T] -> [B,T,d] sinusoidal absolute positions (whisper-style)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / max(1, half - 1))
    ang = pos.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def embed_tokens(cfg: ArchConfig, table: jax.Array, tokens: jax.Array,
                 tp_axis=None, tp_index=None) -> jax.Array:
    if tp_axis is None:
        x = jnp.take(table, tokens, axis=0)
    else:
        vl = table.shape[0]
        local = tokens - tp_index * vl
        ok = (local >= 0) & (local < vl)
        x = jnp.take(table, jnp.clip(local, 0, vl - 1), axis=0)
        x = jnp.where(ok[..., None], x, 0.0)
        x = lax.psum(x, tp_axis)
    if cfg.arch_id.startswith("gemma"):
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def logits_and_xent(cfg: ArchConfig, params: Params, x: jax.Array,
                    labels: jax.Array, tp_axis=None, tp_index=None
                    ) -> jax.Array:
    """Mean cross-entropy; supports vocab-sharded head via the standard
    pmax/psum-decomposed softmax (never materialises gathered logits)."""
    table = params.get("head", params["embed"])
    logits = (x @ table.T).astype(jnp.float32)              # [B,T,Vl]
    if tp_axis is None:
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - lab)
    vl = table.shape[0]
    valid = tp_index * vl + jnp.arange(vl) < cfg.vocab       # mask vocab pad
    logits = jnp.where(valid, logits, -1e30)
    # stop_gradient: the subtracted max is a constant shift (no pmax VJP)
    gmax = lax.pmax(lax.stop_gradient(jnp.max(logits, axis=-1)), tp_axis)
    sumexp = lax.psum(jnp.sum(jnp.exp(logits - gmax[..., None]), axis=-1),
                      tp_axis)
    lse = gmax + jnp.log(sumexp)
    local = labels - tp_index * vl
    ok = (local >= 0) & (local < vl)
    lab = jnp.take_along_axis(logits, jnp.clip(local, 0, vl - 1)[..., None],
                              axis=-1)[..., 0]
    lab = lax.psum(jnp.where(ok, lab, 0.0), tp_axis)
    return jnp.mean(lse - lab)


# ---------------------------------------------------------------------------
# Full forward / loss (single device or per-stage-free path).
# ---------------------------------------------------------------------------

def _scan_layers(cfg: ArchConfig, params: Params, x, meta, *, pos, pos3=None,
                 cache=None, tp_axis=None, tp_index=None):
    def body(carry, inp):
        x, aux = carry
        (lp, ml, cl) = inp
        x, new_cl, a = block_apply(cfg, lp, x, ml, pos=pos, pos3=pos3,
                                   cache_l=cl, tp_axis=tp_axis,
                                   tp_index=tp_index)
        return (x, aux + a), new_cl

    (x, aux), new_cache = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   (params["layers"], meta, cache))
    return x, aux, new_cache


def forward(cfg: ArchConfig, params: Params, batch: dict, *,
            cache=None, tp_axis=None, tp_index=None):
    """Full forward.  ``batch``: tokens [B,T] (+ pos3 for vlm, frames for
    audio).  Returns (hidden, aux, new_cache)."""
    meta = layer_meta(cfg)
    if cfg.family == "audio":
        h_dec = embed_tokens(cfg, params["embed"], batch["tokens"],
                             tp_axis, tp_index)
        pos = batch.get("pos", _default_pos(batch["tokens"], cache))
        h_dec = h_dec + sinusoid_pos(pos, cfg.d_model, h_dec.dtype)
        if "frames" in batch:
            B, S = batch["frames"].shape[:2]
            enc_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            h_enc = (batch["frames"].astype(h_dec.dtype)
                     + sinusoid_pos(enc_pos, cfg.d_model, h_dec.dtype))
        else:   # decode: cross K/V live in the cache, h_enc is vestigial
            h_enc = jnp.zeros((h_dec.shape[0], 1, cfg.d_model), h_dec.dtype)
        x = dict(h_enc=h_enc, h_dec=h_dec)
    elif "embeds" in batch:                                   # vlm stub frontend
        x = batch["embeds"]
        pos = batch.get("pos", _default_pos_from_x(x, cache))
    else:
        x = embed_tokens(cfg, params["embed"], batch["tokens"],
                         tp_axis, tp_index)
        pos = batch.get("pos", _default_pos(batch["tokens"], cache))
    pos3 = batch.get("pos3")
    x, aux, new_cache = _scan_layers(cfg, params, x, meta, pos=pos, pos3=pos3,
                                     cache=cache, tp_axis=tp_axis,
                                     tp_index=tp_index)
    if cfg.family == "audio":
        x = x["h_dec"]
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, new_cache


def _default_pos(tokens, cache):
    B, T = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if cache is not None:
        off = _cache_len(cache)
        pos = pos + (off[:, None] if getattr(off, "ndim", 0) else off)
    return pos


def _default_pos_from_x(x, cache):
    B, T = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if cache is not None:
        off = _cache_len(cache)
        pos = pos + (off[:, None] if getattr(off, "ndim", 0) else off)
    return pos


def _cache_len(cache):
    # cache["kv"]["len"] is stacked [L, B] — per-layer, per-slot offsets
    # (every request row sits at its own sequence position).  Encoder
    # layers never advance theirs (whisper), so reduce layer-like leading
    # axes with max and keep the per-slot [B] vector.
    if isinstance(cache, dict) and "kv" in cache and "len" in cache["kv"]:
        l = cache["kv"]["len"]
        if l.ndim <= 1:
            return jnp.max(l) if l.ndim else l
        return jnp.max(l, axis=tuple(range(l.ndim - 1)))
    return 0


def loss_fn(cfg: ArchConfig, params: Params, batch: dict, *,
            tp_axis=None, tp_index=None) -> jax.Array:
    x, aux, _ = forward(cfg, params, batch, tp_axis=tp_axis, tp_index=tp_index)
    ce = logits_and_xent(cfg, params, x, batch["labels"], tp_axis, tp_index)
    return ce + aux


# ---------------------------------------------------------------------------
# Decode caches.
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, tp: int = 1,
               dtype=jnp.float32, enc_len: int = 0) -> dict:
    """Stacked [L, ...] decode cache for every layer."""
    n = cfg.n_layers
    hd = cfg.resolved_head_dim
    nkv = max(1, cfg.n_kv_heads // tp)
    c: dict = {}
    if cfg.family == "ssm":
        c["ssm"] = _ssm_cache(cfg, n, batch, tp, dtype)
        return c
    if cfg.attn_kind == "mla":
        m = cfg.mla
        c["kv"] = dict(
            c_kv=jnp.zeros((n, batch, max_len, m.kv_lora_rank), dtype),
            k_rope=jnp.zeros((n, batch, max_len, m.qk_rope_dim), dtype),
            len=jnp.zeros((n, batch), jnp.int32))
    else:
        c["kv"] = dict(k=jnp.zeros((n, batch, max_len, nkv, hd), dtype),
                       v=jnp.zeros((n, batch, max_len, nkv, hd), dtype),
                       len=jnp.zeros((n, batch), jnp.int32))
    if cfg.family == "hybrid":
        c["ssm"] = _ssm_cache(cfg, n, batch, tp, dtype)
    if cfg.n_enc_layers:
        nh_l = cfg.n_heads // tp
        c["xk"] = jnp.zeros((n, batch, enc_len, nh_l, hd), dtype)
        c["xv"] = jnp.zeros((n, batch, enc_len, nh_l, hd), dtype)
    return c


def _ssm_cache(cfg: ArchConfig, n: int, batch: int, tp: int, dtype) -> dict:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model // tp
    nh = max(1, s.n_heads(cfg.d_model) // tp)
    conv_ch = d_inner + 2 * s.d_state
    return dict(conv=jnp.zeros((n, batch, s.d_conv - 1, conv_ch), dtype),
                state=jnp.zeros((n, batch, nh, s.head_dim, s.d_state), dtype))


def prefill_audio_cache(cfg: ArchConfig, params: Params, frames: jax.Array,
                        cache: dict, *, tp_axis=None) -> dict:
    """Whisper serving: run the encoder stack once and fill the per-layer
    cross-attention K/V cache consumed by every decode step."""
    B, S = frames.shape[:2]
    meta = layer_meta(cfg)
    enc_pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = frames + sinusoid_pos(enc_pos, cfg.d_model, frames.dtype)
    x = dict(h_enc=h, h_dec=jnp.zeros((B, 1, cfg.d_model), frames.dtype))
    pos = jnp.zeros((B, 1), jnp.int32)
    x, _, _ = _scan_layers(cfg, params, x, meta, pos=pos, tp_axis=tp_axis)
    enc_out = x["h_enc"]
    hd = cfg.resolved_head_dim

    def per_layer(lp):
        nkv = lp["xattn"]["wk"].shape[1] // hd
        xk = (enc_out @ lp["xattn"]["wk"]).reshape(B, S, nkv, hd)
        xv = (enc_out @ lp["xattn"]["wv"]).reshape(B, S, nkv, hd)
        return xk, xv

    xk, xv = jax.vmap(per_layer)(params["layers"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step(cfg: ArchConfig, params: Params, batch: dict, cache: dict,
                *, tp_axis=None, tp_index=None):
    """One-token decode: batch['tokens'] is [B,1].  Returns (logits-hidden,
    new_cache)."""
    x, _, new_cache = forward(cfg, params, batch, cache=cache,
                              tp_axis=tp_axis, tp_index=tp_index)
    table = params.get("head", params["embed"])
    logits = (x @ table.T).astype(jnp.float32)
    return logits, new_cache
