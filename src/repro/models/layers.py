"""Functional building blocks for every assigned architecture.

Everything is a pure function over explicit parameter pytrees so the same
code runs (a) single-device in smoke tests, (b) stacked-and-scanned inside
the shard_map pipeline, and (c) under jax.grad.  When executed inside
``shard_map`` with a tensor-parallel axis, pass ``tp_axis``: head/FFN/expert
dimensions are then interpreted as *local shards* and the functions insert
the matching ``psum``s.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig

Params = dict
PRNGKey = jax.Array


def _maybe_psum(x: jax.Array, axis: Optional[str]) -> jax.Array:
    return lax.psum(x, axis) if axis else x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)          # stored as (scale - 1), gemma-style


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, D]; pos: [B, T] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    ang = pos.astype(jnp.float32)[..., None] * freqs    # [B, T, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, theta: float,
                sections: tuple[int, int, int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  pos3: [3, B, T] (temporal, height, width);
    ``sections`` partitions the half-dim frequency bands among t/h/w."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [D/2]
    ang_thw = pos3.astype(jnp.float32)[..., None] * freqs  # [3, B, T, D/2]
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_thw, 0, -1), sec[None, None, :, None], axis=-1)[..., 0]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core (shared by GQA / MLA / cross-attention)
# ---------------------------------------------------------------------------

ATTN_CHUNK_Q = 512     # flash-style query-chunk size for the XLA path

# When True, inner scans (attention q-chunks, SSD chunks) are unrolled so
# XLA cost_analysis counts every iteration (cost analysis counts a while
# body ONCE).  Set by the dry-run's roofline mode; never for real runs.
UNROLL_SCANS = False


def _block_attend(qg, k, v, qpos, kpos, kv_len, window, causal, scale):
    """One query block.  qg: [B,c,Hkv,G,D]; k/v: [B,S,Hkv,D*].
    qpos: [c] shared or [B,c] per-row (slot-cache offsets); kpos: [S];
    kv_len: valid prefix of k/v — None, scalar, or per-row [B]."""
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = qpos if qpos.ndim == 2 else qpos[None]          # [R,c], R in {1,B}
    m = jnp.ones((qp.shape[0], qp.shape[1], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, None, :] <= qp[:, :, None]
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        m &= kpos[None, None, :] < (kl[:, None, None] if kl.ndim else kl)
    if window is not None:
        w = jnp.asarray(window)
        m &= (kpos[None, None, :] > qp[:, :, None] - w) | (w == 0)
    logits = jnp.where(m[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    B, c = qg.shape[0], qg.shape[1]
    return out.reshape(B, c, -1, v.shape[-1]).astype(qg.dtype)


def attend(q: jax.Array, k: jax.Array, v: jax.Array, *,
           scale: float, causal: bool = True, q_start=0,
           kv_len=None, window=None,
           chunk: int = ATTN_CHUNK_Q) -> jax.Array:
    """Memory-bounded attention: scans over query chunks so no [T,S] logits
    tensor is ever materialised (the XLA analogue of the Pallas flash
    kernel in repro.kernels; backward rematerialises each chunk).

    q: [B,T,Hq,D], k/v: [B,S,Hkv,D*] (GQA by head-group broadcast).
    ``q_start``: absolute position of q[0] — a scalar cache offset shared
    by the batch, or a per-row [B] vector of slot offsets (continuous
    batching: every row sits at its own sequence position);
    ``kv_len``: valid prefix of k/v (scalar or per-row [B]) or None;
    ``window``: sliding window size (0/None = global; may be traced).
    """
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, T, Hkv, G, D)
    q_start = jnp.asarray(q_start)
    q_base = q_start[:, None] if q_start.ndim else q_start
    kpos = jnp.arange(S)
    if T % chunk != 0:
        # pick the largest divisor of T <= chunk (falls back to one block
        # for small awkward lengths like whisper's 1500 frames)
        c = min(T, chunk)
        while T % c:
            c -= 1
        chunk = c if c >= chunk // 4 else T
    if T <= chunk:
        qpos = q_base + jnp.arange(T)
        out = _block_attend(qg, k, v, qpos, kpos, kv_len, window, causal, scale)
        return out.reshape(B, T, Hq, v.shape[-1])
    assert T % chunk == 0, (T, chunk)
    nq = T // chunk
    qg_c = qg.reshape(B, nq, chunk, Hkv, G, D)

    @jax.checkpoint
    def body(_, inp):
        qc, idx = inp
        qpos = q_base + idx * chunk + jnp.arange(chunk)
        return None, _block_attend(qc, k, v, qpos, kpos, kv_len, window,
                                   causal, scale)

    _, out = lax.scan(body, None, (jnp.moveaxis(qg_c, 1, 0), jnp.arange(nq)),
                      unroll=UNROLL_SCANS)
    out = jnp.moveaxis(out, 0, 1).reshape(B, T, Hq, v.shape[-1])
    return out


def _cache_write(buf, new, idx):
    """Write ``new`` [B,T,...] into the sequence axis (dim 1) of ``buf``
    [B,S,...] at offset ``idx`` — a scalar shared by the batch, or a
    per-row [B] vector of slot offsets (each request's ring position)."""
    new = new.astype(buf.dtype)
    if idx.ndim == 0:
        start = (0, idx) + (0,) * (buf.ndim - 2)
        return lax.dynamic_update_slice(buf, new, start)
    per_row = lambda b, u, i: lax.dynamic_update_slice(
        b, u, (i,) + (0,) * (b.ndim - 1))
    return jax.vmap(per_row)(buf, new, idx)


# ---------------------------------------------------------------------------
# GQA attention block (llama / qwen / gemma / hymba / whisper flavours)
# ---------------------------------------------------------------------------

def init_gqa(key: PRNGKey, cfg: ArchConfig, tp: int, dtype) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads // tp, max(1, cfg.n_kv_heads // tp)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = dict(
        wq=jax.random.normal(k1, (d, nh * hd), dtype) * s,
        wk=jax.random.normal(k2, (d, nkv * hd), dtype) * s,
        wv=jax.random.normal(k3, (d, nkv * hd), dtype) * s,
        wo=jax.random.normal(k4, (nh * hd, d), dtype) * s / math.sqrt(2 * cfg.n_layers),
    )
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dtype)
        p["k_norm"] = init_rms_norm(hd, dtype)
    return p


def _slice_kv_heads(w: jax.Array, cfg: ArchConfig, nh_l: int, hd: int,
                    tp_index) -> jax.Array:
    """When KV projections are replicated (n_kv_heads ∤ tensor), slice out
    the kv head(s) this device's query shard actually attends."""
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    nkv_have = w.shape[-1] // hd
    if nkv_have != nkv or nh_l == nh or tp_index is None:
        return w                         # properly sharded already
    g = nh // nkv                        # q heads per kv head
    need = max(1, nh_l // g)
    start = jnp.asarray(tp_index) * nh_l // g
    w3 = lax.dynamic_slice(w.reshape(w.shape[0], nkv, hd),
                           (jnp.zeros((), start.dtype), start,
                            jnp.zeros((), start.dtype)),
                           (w.shape[0], need, hd))
    return w3.reshape(w.shape[0], need * hd)


def gqa_attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  pos: jax.Array, is_global, window_mask_extra=None,
                  rope_theta, cache: Optional[dict] = None,
                  cur_len=None, tp_axis: Optional[str] = None,
                  tp_index=None,
                  pos3: Optional[jax.Array] = None) -> tuple[jax.Array, Optional[dict]]:
    """One GQA self-attention. ``is_global`` (traced bool) selects global vs
    sliding-window masking; ``rope_theta`` may be traced (per-layer)."""
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    nh_l = p["wq"].shape[1] // hd
    wk = _slice_kv_heads(p["wk"], cfg, nh_l, hd, tp_index)
    wv = _slice_kv_heads(p["wv"], cfg, nh_l, hd, tp_index)
    nkv_l = wk.shape[1] // hd
    q = (x @ p["wq"]).reshape(B, T, nh_l, hd)
    k = (x @ wk).reshape(B, T, nkv_l, hd)
    v = (x @ wv).reshape(B, T, nkv_l, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections is not None and pos3 is not None:
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta:      # static off-switch (whisper: learned abs pos)
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    new_cache = None
    win = (jnp.where(is_global, 0, cfg.window) if cfg.window else None)
    if cache is not None:
        idx = jnp.asarray(cache["len"])
        ck = _cache_write(cache["k"], k, idx)
        cv = _cache_write(cache["v"], v, idx)
        new_cache = dict(k=ck, v=cv, len=idx + T)
        out = attend(q, ck, cv, scale=1.0 / math.sqrt(hd), causal=True,
                     q_start=idx, kv_len=idx + T, window=win)
    else:
        out = attend(q, k, v, scale=1.0 / math.sqrt(hd), causal=True,
                     window=win)
    out = out.reshape(B, T, nh_l * hd) @ p["wo"]
    return _maybe_psum(out, tp_axis), new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross(key: PRNGKey, cfg: ArchConfig, tp: int, dtype) -> Params:
    return init_gqa(key, cfg, tp, dtype)


def cross_attention(p: Params, x: jax.Array, enc: jax.Array, cfg: ArchConfig,
                    tp_axis: Optional[str] = None) -> jax.Array:
    B, T, _ = x.shape
    S = enc.shape[1]
    hd = cfg.resolved_head_dim
    nh_l = p["wq"].shape[1] // hd
    nkv_l = p["wk"].shape[1] // hd
    q = (x @ p["wq"]).reshape(B, T, nh_l, hd)
    k = (enc @ p["wk"]).reshape(B, S, nkv_l, hd)
    v = (enc @ p["wv"]).reshape(B, S, nkv_l, hd)
    out = attend(q, k, v, scale=1.0 / math.sqrt(hd), causal=False)
    out = out.reshape(B, T, nh_l * hd) @ p["wo"]
    return _maybe_psum(out, tp_axis)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek V2/V3, MiniCPM3)
# ---------------------------------------------------------------------------

def init_mla(key: PRNGKey, cfg: ArchConfig, tp: int, dtype) -> Params:
    m = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads // tp
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    p = dict(
        wkv_a=jax.random.normal(ks[0], (d, m.kv_lora_rank + m.qk_rope_dim), dtype) * s,
        kv_norm=init_rms_norm(m.kv_lora_rank, dtype),
        wkv_b=jax.random.normal(ks[1], (m.kv_lora_rank,
                                        nh * (m.qk_nope_dim + m.v_head_dim)), dtype)
        * (1.0 / math.sqrt(m.kv_lora_rank)),
        wo=jax.random.normal(ks[2], (nh * m.v_head_dim, d), dtype)
        * s / math.sqrt(2 * cfg.n_layers),
    )
    if m.q_lora_rank:
        p["wq_a"] = jax.random.normal(ks[3], (d, m.q_lora_rank), dtype) * s
        p["q_norm"] = init_rms_norm(m.q_lora_rank, dtype)
        p["wq_b"] = jax.random.normal(
            ks[4], (m.q_lora_rank, nh * (m.qk_nope_dim + m.qk_rope_dim)), dtype) \
            * (1.0 / math.sqrt(m.q_lora_rank))
    else:
        p["wq"] = jax.random.normal(
            ks[4], (d, nh * (m.qk_nope_dim + m.qk_rope_dim)), dtype) * s
    return p


def mla_attention(p: Params, x: jax.Array, cfg: ArchConfig, *,
                  pos: jax.Array, cache: Optional[dict] = None,
                  tp_axis: Optional[str] = None) -> tuple[jax.Array, Optional[dict]]:
    """MLA with the compressed-KV cache.  Prefill/train uses the expanded
    path; decode uses the *absorbed* path (scores and values computed
    directly against the latent cache — the technique that makes the MLA
    cache O(kv_lora) instead of O(heads*dim))."""
    m = cfg.mla
    B, T, _ = x.shape
    nh_l = p["wo"].shape[0] // m.v_head_dim
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    scale = 1.0 / math.sqrt(qk_dim)
    # --- queries -----------------------------------------------------------
    if m.q_lora_rank:
        q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(B, T, nh_l, qk_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    # --- latent kv ----------------------------------------------------------
    kv = x @ p["wkv_a"]                                   # [B,T,r+rope]
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], pos, cfg.rope_theta)[:, :, 0]
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, nh_l, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_dim]                     # [r, H, nope]
    w_uv = wkv_b[..., m.qk_nope_dim:]                     # [r, H, v]
    new_cache = None
    if cache is not None:
        idx = jnp.asarray(cache["len"])
        cc = _cache_write(cache["c_kv"], c_kv, idx)
        cr = _cache_write(cache["k_rope"], k_rope, idx)
        new_cache = dict(c_kv=cc, k_rope=cr, len=idx + T)
    if T == 1 and cache is not None:
        # absorbed decode: score and read out directly against the latent
        # cache; never materialises per-head K/V of the full context.
        S = cc.shape[1]
        q_lat = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        logits = (jnp.einsum("bthr,bsr->bhts", q_lat, cc.astype(jnp.float32))
                  + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                               cr.astype(jnp.float32))) * scale
        kl = idx + T                                      # scalar or [B]
        kl = kl[:, None, None, None] if kl.ndim else kl
        mask = (jnp.arange(S)[None, None, None, :] < kl)
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bhts,bsr->bthr", probs, cc.astype(jnp.float32))
        out = jnp.einsum("bthr,rhd->bthd", ctx,
                         w_uv.astype(jnp.float32)).astype(x.dtype)
    else:
        # expanded path (train / prefill): per-head K/V from the latent.
        src_c, src_r = (c_kv, k_rope) if cache is None else (cc, cr)
        kv_len = None if cache is None else idx + T
        q_start = 0 if cache is None else idx
        Skv = src_c.shape[1]
        k_nope = jnp.einsum("bsr,rhd->bshd", src_c, w_uk)
        v = jnp.einsum("bsr,rhd->bshd", src_c, w_uv)
        k = jnp.concatenate([k_nope,
                             jnp.broadcast_to(src_r[:, :, None],
                                              (B, Skv, nh_l, m.qk_rope_dim))], -1)
        qf = jnp.concatenate([q_nope, q_rope], -1)
        out = attend(qf, k, v, scale=scale, causal=True, q_start=q_start,
                     kv_len=kv_len)
    out = out.reshape(B, T, nh_l * m.v_head_dim) @ p["wo"]
    return _maybe_psum(out, tp_axis), new_cache


# ---------------------------------------------------------------------------
# Dense (gated) MLP
# ---------------------------------------------------------------------------

def init_mlp(key: PRNGKey, d: int, ff: int, tp: int, n_layers: int, dtype) -> Params:
    ffl = ff // tp
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return dict(
        w1=jax.random.normal(k1, (d, ffl), dtype) * s,
        w3=jax.random.normal(k2, (d, ffl), dtype) * s,
        w2=jax.random.normal(k3, (ffl, d), dtype)
        * (1.0 / math.sqrt(ff)) / math.sqrt(2 * n_layers),
    )


def _act(x, kind):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def mlp(p: Params, x: jax.Array, act: str = "silu",
        tp_axis: Optional[str] = None) -> jax.Array:
    h = _act(x @ p["w1"], act) * (x @ p["w3"])
    return _maybe_psum(h @ p["w2"], tp_axis)


# ---------------------------------------------------------------------------
# Mixture of Experts (DeepSeek-style: shared + routed top-k)
# ---------------------------------------------------------------------------

def init_moe(key: PRNGKey, cfg: ArchConfig, tp: int, dtype) -> Params:
    mo = cfg.moe
    d, ffe = cfg.d_model, mo.d_ff_expert
    e_l = max(1, mo.n_routed // tp)
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    so = (1.0 / math.sqrt(ffe)) / math.sqrt(2 * cfg.n_layers)
    p = dict(
        router=jax.random.normal(ks[0], (d, mo.n_routed), jnp.float32) * s,
        we1=jax.random.normal(ks[1], (e_l, d, ffe), dtype) * s,
        we3=jax.random.normal(ks[2], (e_l, d, ffe), dtype) * s,
        we2=jax.random.normal(ks[3], (e_l, ffe, d), dtype) * so,
    )
    if mo.n_shared:
        p["shared"] = init_mlp(ks[4], d, mo.n_shared * ffe, tp, cfg.n_layers, dtype)
    return p


def moe_block(p: Params, x: jax.Array, cfg: ArchConfig, act: str = "silu",
              tp_axis: Optional[str] = None,
              tp_index: Optional[jax.Array] = None,
              dp_axis: Optional[str] = None,
              dp_index: Optional[jax.Array] = None,
              n_dp: int = 1) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k MoE with gather/scatter dispatch (no dense
    one-hot matmuls — compiled FLOPs stay ~top_k/E of the dense cost).

    Expert sharding (DeepSeek/GShard-style, TPU-idiomatic):
    * over ``tp_axis``  — tokens are replicated across the tensor axis, each
      device computes its expert slice, outputs are psum-combined;
    * over ``dp_axis``  — tokens are batch-sharded, so capacity-bucketed
      token buffers travel by ``all_to_all`` to the data shard owning the
      expert (cfg.moe.ep_data), are computed, and travel back.
    Both can be active: experts split data-major, then tensor.

    Capacity: C = ceil(k·N/E · capacity_factor) slots per expert per source
    shard; overflowing assignments are dropped (standard token-choice).

    Returns (output, aux_load_balance_loss)."""
    import math as _math
    mo = cfg.moe
    B, T, d = x.shape
    N = B * T
    E = mo.n_routed
    k = mo.top_k
    e_loc = p["we1"].shape[0]              # experts owned by this device
    e_dp = E // n_dp                       # experts per data shard
    xt = x.reshape(N, d)
    # ---- routing (replicated math: router weights are not sharded) -------
    logits = (xt.astype(jnp.float32) @ p["router"])          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)                         # [N, k]
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    # ---- capacity bucketing ----------------------------------------------
    C = max(1, _math.ceil(k * N / E * mo.capacity_factor))
    e_flat = topi.reshape(-1)                                # [A], A = N*k
    w_flat = topv.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(N), k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)      # [A, E]
    pos = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(e_flat.shape[0]),
                                                e_flat]      # rank in expert
    valid = pos < C
    shard = e_flat // e_dp                                   # dest data shard
    e_in = e_flat % e_dp
    slot = jnp.where(valid, (shard * e_dp + e_in) * C + pos,
                     n_dp * e_dp * C)                        # OOB -> dropped
    x_send = jnp.zeros((n_dp * e_dp * C, d), xt.dtype).at[slot].set(
        xt[tok_flat], mode="drop")
    x_send = x_send.reshape(n_dp, e_dp, C, d)
    # ---- all_to_all over the batch-sharded expert axis --------------------
    if dp_axis is not None and n_dp > 1:
        x_recv = lax.all_to_all(x_send, dp_axis, split_axis=0, concat_axis=0)
    else:
        x_recv = x_send                                      # [src=1, E, C, d]
    n_src = x_recv.shape[0]
    # ---- tensor slice of this data shard's experts ------------------------
    if tp_index is not None and e_loc < e_dp:
        start = tp_index * e_loc
        xe = lax.dynamic_slice_in_dim(
            jnp.moveaxis(x_recv, 1, 0), start, e_loc, 0)     # [e_loc,src,C,d]
    else:
        xe = jnp.moveaxis(x_recv, 1, 0)                      # [e_loc,src,C,d]
    xe = xe.reshape(e_loc, n_src * C, d)
    # ---- expert FFN --------------------------------------------------------
    h = _act(jnp.einsum("ecd,edf->ecf", xe, p["we1"]), act) \
        * jnp.einsum("ecd,edf->ecf", xe, p["we3"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["we2"])             # [e_loc,srcC,d]
    ye = ye.reshape(e_loc, n_src, C, d)
    # ---- route back --------------------------------------------------------
    if tp_index is not None and e_loc < e_dp:
        y_full = jnp.zeros((e_dp, n_src, C, d), ye.dtype)
        y_full = lax.dynamic_update_slice_in_dim(y_full, ye, tp_index * e_loc, 0)
    else:
        y_full = ye
    y_back = jnp.moveaxis(y_full, 0, 1)                      # [src, e_dp, C, d]
    if dp_axis is not None and n_dp > 1:
        y_back = lax.all_to_all(y_back, dp_axis, split_axis=0, concat_axis=0)
    y_slots = y_back.reshape(n_dp * e_dp * C, d)
    y_a = jnp.take(y_slots, jnp.clip(slot, 0, n_dp * e_dp * C - 1), axis=0)
    contrib = jnp.where(valid[:, None], y_a.astype(jnp.float32)
                        * w_flat[:, None], 0.0)
    y = jnp.zeros((N, d), jnp.float32).at[tok_flat].add(contrib)
    y = _maybe_psum(y, tp_axis)
    # name the routed-expert output so collective-aware remat policies can
    # save it: recomputing it in backward re-executes the all_to_alls
    from jax.ad_checkpoint import checkpoint_name as _ckpt_name
    y = _ckpt_name(y, "moe_y")
    if "shared" in p:
        y = y + mlp(p["shared"], xt, act, tp_axis).astype(jnp.float32)
    # ---- load-balance aux loss (Switch-style): E * sum_e f_e * p_e --------
    frac = jnp.mean(jnp.sum(jax.nn.one_hot(topi, E), axis=1), axis=0)   # [E]
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * pmean) * mo.router_aux_weight
    return y.reshape(B, T, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD — state-space duality, chunked)
# ---------------------------------------------------------------------------

def init_ssm(key: PRNGKey, cfg: ArchConfig, tp: int, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d // tp
    nh = max(1, s.n_heads(d) // tp)
    conv_ch = d_inner + 2 * s.d_state
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(d)
    return dict(
        in_proj=jax.random.normal(ks[0], (d, 2 * d_inner + 2 * s.d_state + nh),
                                  dtype) * sc,
        conv_w=jax.random.normal(ks[1], (s.d_conv, conv_ch), dtype) * 0.1,
        conv_b=jnp.zeros((conv_ch,), dtype),
        a_log=jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        d_skip=jnp.ones((nh,), jnp.float32),
        dt_bias=jnp.zeros((nh,), jnp.float32),
        gate_norm=init_rms_norm(d_inner, dtype),
        out_proj=jax.random.normal(ks[2], (d_inner, d), dtype)
        * (1.0 / math.sqrt(s.expand * d)) / math.sqrt(2 * cfg.n_layers),
    )


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD scan (Mamba-2, arXiv:2405.21060 §6), one chunk at a time.

    A single ``lax.scan`` over chunks carries the [B,H,P,N] state; each
    chunk does the quadratic intra-chunk block plus the carried-state
    readout, so peak memory is O(chunk²·H) rather than O(T·chunk·H).

    xh: [B,T,H,P], dt: [B,T,H], A: [H] (negative), Bm/Cm: [B,T,N].
    Returns (y: [B,T,H,P], final_state: [B,H,P,N]).
    """
    Bsz, T, H, P = xh.shape
    N = Bm.shape[-1]
    nc = T // chunk
    x_ = jnp.moveaxis(xh.reshape(Bsz, nc, chunk, H, P), 1, 0)
    dt_ = jnp.moveaxis(dt.reshape(Bsz, nc, chunk, H), 1, 0)
    B_ = jnp.moveaxis(Bm.reshape(Bsz, nc, chunk, N), 1, 0)
    C_ = jnp.moveaxis(Cm.reshape(Bsz, nc, chunk, N), 1, 0)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def step(state, inp):
        xc, dtc, Bc, Cc = inp                       # [B,c,H,P] [B,c,H] [B,c,N]
        dA = dtc.astype(jnp.float32) * A[None, None, :]         # [B,c,H] (<0)
        dA_cum = jnp.cumsum(dA, axis=1)
        # intra-chunk (mask BEFORE exp so masked entries don't produce
        # inf*0 NaNs in the backward pass)
        seg = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]     # [B,c,c,H]
        Lmat = jnp.exp(jnp.where(causal[None, :, :, None], seg, -1e30))
        scores = jnp.einsum("bcn,bsn->bcs", Cc.astype(jnp.float32),
                            Bc.astype(jnp.float32))
        y_diag = jnp.einsum("bcs,bcsh,bsh,bshp->bchp", scores, Lmat,
                            dtc.astype(jnp.float32), xc.astype(jnp.float32))
        # carried-state readout
        state_decay = jnp.exp(dA_cum)                           # [B,c,H]
        y_off = jnp.einsum("bcn,bch,bhpn->bchp",
                           Cc.astype(jnp.float32), state_decay, state)
        # state update
        decay_to_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)      # [B,c,H]
        chunk_state = jnp.einsum("bsn,bsh,bsh,bshp->bhpn",
                                 Bc.astype(jnp.float32), decay_to_end,
                                 dtc.astype(jnp.float32), xc.astype(jnp.float32))
        chunk_decay = jnp.exp(dA_cum[:, -1, :])                 # [B,H]
        new_state = state * chunk_decay[:, :, None, None] + chunk_state
        return new_state, (y_diag + y_off).astype(xh.dtype)

    final, y = lax.scan(step, s0, (x_, dt_, B_, C_), unroll=UNROLL_SCANS)
    y = jnp.moveaxis(y, 0, 1).reshape(Bsz, T, H, P)
    return y, final


def ssm_block(p: Params, x: jax.Array, cfg: ArchConfig,
              cache: Optional[dict] = None,
              tp_axis: Optional[str] = None) -> tuple[jax.Array, Optional[dict]]:
    """Mamba-2 block: in_proj -> causal depthwise conv -> SSD -> gated norm
    -> out_proj.  Decode path is the O(1) recurrent update."""
    s = cfg.ssm
    B, T, d = x.shape
    d_inner = p["out_proj"].shape[0]
    nh = p["a_log"].shape[0]
    P = s.head_dim
    N = s.d_state
    zxbcdt = x @ p["in_proj"]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], -1)
    conv_in = jnp.concatenate([xin, Bm, Cm], -1)             # [B,T,conv_ch]
    new_cache = None
    if cache is None:
        pad = jnp.pad(conv_in, ((0, 0), (s.d_conv - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + T] * p["conv_w"][i] for i in range(s.d_conv))
        conv = jax.nn.silu(conv + p["conv_b"])
    else:
        window = jnp.concatenate([cache["conv"], conv_in], axis=1)  # [B,dc-1+T,ch]
        conv = sum(window[:, i:i + T] * p["conv_w"][i] for i in range(s.d_conv))
        conv = jax.nn.silu(conv + p["conv_b"])
        new_conv = window[:, -(s.d_conv - 1):]
    xc, Bc, Cc = jnp.split(conv, [d_inner, d_inner + N], -1)
    xh = xc.reshape(B, T, nh, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,T,H]
    A = -jnp.exp(p["a_log"])                                  # [H] negative
    if cache is None:
        y, _ = _ssd_chunked(xh, dt, A, Bc, Cc, min(s.chunk, T))
        y = y.astype(jnp.float32)
    elif T > 1:
        # prefill with state cache: chunked SSD seeded by the cached state
        y, final = _ssd_chunked(xh, dt, A, Bc, Cc, min(s.chunk, T),
                                init_state=cache["state"])
        y = y.astype(jnp.float32)
        new_cache = dict(conv=window[:, -(s.d_conv - 1):],
                         state=final.astype(cache["state"].dtype))
    else:
        st = cache["state"].astype(jnp.float32)               # [B,H,P,N]
        dA = jnp.exp(dt[:, 0] * A[None])                      # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0],
                         xh[:, 0].astype(jnp.float32), Bc[:, 0].astype(jnp.float32))
        st = st * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), st)
        y = y[:, None]                                        # [B,1,H,P]
        new_cache = dict(conv=new_conv, state=st.astype(cache["state"].dtype))
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return _maybe_psum(out, tp_axis), new_cache
