"""BaPipe balanced-partition exploration (paper §3.3).

Pipeline of refinements:

1. **Inter-layer partition** — Eq.(1) harmonic initialisation followed by
   iterative load balancing.  We implement the iteration's fixed point
   exactly: an O(L²·N) dynamic program over contiguous layer ranges that
   minimises the bottleneck stage time on a (possibly heterogeneous) device
   chain.
2. **Coarse-grained partition on communication** — when a stage's boundary
   transfer time exceeds its compute time, restrict cut points to layer
   boundaries whose activation size is ≤ a_th (merge the rest into
   super-layers) and re-run the DP.
3. **Intra-layer partition** — fractional split of the boundary layer
   between adjacent stages (FPDeep-style); only applied when communication
   is not the bottleneck.  Realised on TPU as tensor-parallel sharding.
4. **Memory fine-tuning** — shift boundary layers away from stages whose
   schedule-dependent memory requirement exceeds device capacity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.hardware import ClusterSpec, DeviceSpec
from repro.core.profiler import (LayerProfile, NetworkProfile, bwd_time,
                                 bwd_split_time_tp, comm_time, fwd_time,
                                 fwd_time_tp)


@dataclasses.dataclass
class StageCost:
    fwd: float
    bwd: float
    comm_in: float
    comm_out: float
    weight_bytes: float
    act_out_bytes: float     # per micro-batch boundary activation
    bwd_w: float = 0.0       # weight-gradient share of bwd (seconds),
                             # from the layers' profiled w_frac; 0 =>
                             # unknown, treated as the even split

    def compute(self) -> float:
        return self.fwd + self.bwd

    def total(self, overlap: bool) -> float:
        c = max(self.comm_in, self.comm_out)
        return max(self.compute(), 2 * c) if overlap else self.compute() + 2 * c

    def bw_split(self) -> tuple[float, float]:
        """(input-gradient, weight-gradient) split of ``bwd`` — the
        profiled split when known, else the even split."""
        if 0.0 < self.bwd_w < self.bwd:
            return self.bwd - self.bwd_w, self.bwd_w
        return self.bwd / 2.0, self.bwd / 2.0


@dataclasses.dataclass
class PartitionPlan:
    """``bounds``/``stage_costs`` have one entry per *chunk*: N entries for
    the contiguous schedules (V == 1), N*V entries for interleaved plans
    where chunk (virtual stage) ``v*N + n`` runs on physical device n."""

    bounds: tuple[tuple[int, int], ...]     # per-chunk [start, end) layer range
    stage_costs: tuple[StageCost, ...]
    bottleneck: float                        # max per-stage total time
    overlap: bool
    frac_shift: tuple[float, ...] = ()       # intra-layer fractional refinement
    V: int = 1                               # virtual-stage interleave depth

    @property
    def n_stages(self) -> int:
        """Number of physical devices (pipeline stages)."""
        return len(self.bounds) // self.V

    def layers_per_stage(self) -> list[int]:
        return [e - s for s, e in self.bounds]

    def device_chunks(self, n: int) -> list[int]:
        """Chunk indices owned by device n (Megatron-style assignment)."""
        return [v * self.n_stages + n for v in range(self.V)]

    def device_costs(self) -> tuple[StageCost, ...]:
        """Per-physical-device costs: a device's V chunks aggregated
        (compute and weights sum; boundary terms take the worst chunk)."""
        if self.V == 1:
            return self.stage_costs
        out = []
        for n in range(self.n_stages):
            cs = [self.stage_costs[j] for j in self.device_chunks(n)]
            out.append(StageCost(
                fwd=sum(c.fwd for c in cs),
                bwd=sum(c.bwd for c in cs),
                comm_in=max(c.comm_in for c in cs),
                comm_out=max(c.comm_out for c in cs),
                weight_bytes=sum(c.weight_bytes for c in cs),
                act_out_bytes=max(c.act_out_bytes for c in cs),
                bwd_w=sum(c.bwd_w for c in cs)))
        return tuple(out)

    def cost_vector(self):
        """The partition's first-class per-device cost vector
        (:class:`repro.core.schedplan.StageCosts`): per-device forward
        time, the profiled input-/weight-gradient backward split, and
        per-*hop* SR from each boundary's actual link bandwidth — the
        interface the cost-shaped schedules consume instead of the
        bottleneck scalar collapse ``(max F, max B, max SR)``."""
        from repro.core.schedplan import StageCosts
        cs = self.device_costs()
        F, B, W = [], [], []
        for c in cs:
            b, bw = c.bw_split()
            F.append(c.fwd)
            B.append(b)
            W.append(bw)
        # degenerate stages (zero-compute profiles) get an epsilon floor
        # so the vector stays a valid schedule-cost input
        eps = max(max(F + B + W, default=1.0), 1.0) * 1e-12
        return StageCosts(
            F=tuple(max(f, eps) for f in F),
            B=tuple(max(b, eps) for b in B),
            W=tuple(max(w, eps) for w in W),
            SR=tuple(cs[i].comm_out for i in range(len(cs) - 1)))

    def balanced_F(self) -> float:
        return max(c.fwd for c in self.device_costs())

    def balanced_B(self) -> float:
        return max(c.bwd for c in self.device_costs())

    def bottleneck_FB(self) -> tuple[float, float]:
        """(fwd, bwd) of the bottleneck-compute device (the pair the
        schedule formulas should see — independent maxima overcount)."""
        c = max(self.device_costs(), key=lambda c: c.compute())
        return c.fwd, c.bwd

    def max_boundary_act(self) -> float:
        return max((c.act_out_bytes for c in self.stage_costs[:-1]), default=0.0)


# ---------------------------------------------------------------------------
# Cost of a contiguous layer range on a given device.
# ---------------------------------------------------------------------------

def _range_cost(prof: NetworkProfile, cluster: ClusterSpec, n: int,
                s: int, e: int, mb: int, include_embed_head: bool) -> StageCost:
    dev = cluster.devices[n]
    fwd = sum(fwd_time(prof.layers[k], dev, mb) for k in range(s, e))
    bwd = sum(bwd_time(prof.layers[k], dev, mb) for k in range(s, e))
    bwd_w = sum(bwd_time(prof.layers[k], dev, mb) * prof.layers[k].w_frac
                for k in range(s, e))
    wbytes = sum(prof.layers[k].bytes_weights for k in range(s, e))
    if include_embed_head:
        if n == 0 and prof.embed is not None:
            fwd += fwd_time(prof.embed, dev, mb)
            bwd += bwd_time(prof.embed, dev, mb)
            bwd_w += bwd_time(prof.embed, dev, mb) * prof.embed.w_frac
            wbytes += prof.embed.bytes_weights
        if n == cluster.n - 1 and prof.head is not None:
            fwd += fwd_time(prof.head, dev, mb)
            bwd += bwd_time(prof.head, dev, mb)
            bwd_w += bwd_time(prof.head, dev, mb) * prof.head.w_frac
            wbytes += prof.head.bytes_weights
    act_in = prof.layers[s - 1].bytes_act_out * mb if s > 0 else 0.0
    act_out = prof.layers[e - 1].bytes_act_out * mb if e < prof.n_layers else 0.0
    ci = comm_time(act_in, cluster.link_bandwidth(n - 1)) if n > 0 else 0.0
    co = comm_time(act_out, cluster.link_bandwidth(n)) if n < cluster.n - 1 else 0.0
    return StageCost(fwd=fwd, bwd=bwd, comm_in=ci, comm_out=co,
                     weight_bytes=wbytes,
                     act_out_bytes=prof.layers[e - 1].bytes_act_out * mb
                     if e - 1 < prof.n_layers else 0.0,
                     bwd_w=bwd_w)


# ---------------------------------------------------------------------------
# Eq.(1) initialisation.
# ---------------------------------------------------------------------------

def eq1_targets(prof: NetworkProfile, cluster: ClusterSpec, mb: int) -> list[float]:
    """Per-stage target times from T = 1 / sum(1/T_n) (paper Eq. 1)."""
    T_n = []
    for dev in cluster.devices:
        T_n.append(sum(fwd_time(l, dev, mb) + bwd_time(l, dev, mb)
                       for l in prof.layers))
    T = 1.0 / sum(1.0 / t for t in T_n)
    return [T] * cluster.n


def eq1_partition(prof: NetworkProfile, cluster: ClusterSpec, mb: int,
                  overlap: bool = True) -> PartitionPlan:
    """Greedy sweep to the Eq.(1) harmonic target (the paper's init step)."""
    T_n = [sum(fwd_time(l, d, mb) + bwd_time(l, d, mb) for l in prof.layers)
           for d in cluster.devices]
    T = 1.0 / sum(1.0 / t for t in T_n)
    bounds, s = [], 0
    L, N = prof.n_layers, cluster.n
    for n in range(N):
        if n == N - 1:
            e = L
        else:
            acc, e = 0.0, s
            dev = cluster.devices[n]
            while e < L - (N - 1 - n):        # leave >=1 layer per later stage
                step = (fwd_time(prof.layers[e], dev, mb)
                        + bwd_time(prof.layers[e], dev, mb))
                if acc + step > T and e > s:
                    break
                acc += step
                e += 1
            e = max(e, s + 1)
        bounds.append((s, e))
        s = e
    return _finalize(prof, cluster, tuple(bounds), mb, overlap)


# ---------------------------------------------------------------------------
# Exact contiguous-partition DP (the load-balancing iteration's fixed point).
# ---------------------------------------------------------------------------

def dp_partition(prof: NetworkProfile, cluster: ClusterSpec, mb: int,
                 overlap: bool = True,
                 allowed_cuts: Optional[set[int]] = None,
                 include_embed_head: bool = True) -> PartitionPlan:
    """Minimise the bottleneck stage time over contiguous partitions.

    ``allowed_cuts``: set of layer indices where a stage boundary may be
    placed (coarse-grained communication partition restricts this).
    """
    L, N = prof.n_layers, cluster.n
    if N > L:
        raise ValueError(f"more stages ({N}) than layers ({L})")
    cuts = allowed_cuts if allowed_cuts is not None else set(range(1, L))
    # O(1) range costs via per-device prefix sums
    pre_f = []   # pre_f[n][i] = sum of fwd+bwd time of layers [0, i) on dev n
    for dev in cluster.devices:
        acc, arr = 0.0, [0.0]
        for l in prof.layers:
            acc += fwd_time(l, dev, mb) + bwd_time(l, dev, mb)
            arr.append(acc)
        pre_f.append(arr)

    def rc(n: int, s: int, e: int) -> float:
        dev = cluster.devices[n]
        t = pre_f[n][e] - pre_f[n][s]
        if include_embed_head:
            if n == 0 and prof.embed is not None:
                t += fwd_time(prof.embed, dev, mb) + bwd_time(prof.embed, dev, mb)
            if n == N - 1 and prof.head is not None:
                t += fwd_time(prof.head, dev, mb) + bwd_time(prof.head, dev, mb)
        act_in = prof.layers[s - 1].bytes_act_out * mb if s > 0 else 0.0
        act_out = prof.layers[e - 1].bytes_act_out * mb if e < L else 0.0
        ci = comm_time(act_in, cluster.link_bandwidth(n - 1)) if n > 0 else 0.0
        co = comm_time(act_out, cluster.link_bandwidth(n)) if n < N - 1 else 0.0
        c = max(ci, co)
        return max(t, 2 * c) if overlap else t + 2 * c

    INF = float("inf")
    # best[n][e] = minimal bottleneck assigning layers [0,e) to stages [0,n]
    best = [[INF] * (L + 1) for _ in range(N)]
    arg = [[-1] * (L + 1) for _ in range(N)]
    for e in range(1, L + 1):
        if e == L or e in cuts:
            best[0][e] = rc(0, 0, e)
    for n in range(1, N):
        for e in range(n + 1, L + 1):
            if e != L and e not in cuts:
                continue
            for s in range(n, e):
                if s != 0 and s not in cuts:
                    continue
                if best[n - 1][s] == INF:
                    continue
                v = max(best[n - 1][s], rc(n, s, e))
                if v < best[n][e]:
                    best[n][e] = v
                    arg[n][e] = s
    if best[N - 1][L] == INF:
        raise ValueError("no feasible partition under allowed cuts")
    bounds, e = [], L
    for n in range(N - 1, 0, -1):
        s = arg[n][e]
        bounds.append((s, e))
        e = s
    bounds.append((0, e))
    bounds.reverse()
    return _finalize(prof, cluster, tuple(bounds), mb, overlap,
                     include_embed_head)


def _finalize(prof: NetworkProfile, cluster: ClusterSpec,
              bounds: tuple[tuple[int, int], ...], mb: int, overlap: bool,
              include_embed_head: bool = True) -> PartitionPlan:
    costs = tuple(_range_cost(prof, cluster, n, s, e, mb, include_embed_head)
                  for n, (s, e) in enumerate(bounds))
    bott = max(c.total(overlap) for c in costs)
    return PartitionPlan(bounds=bounds, stage_costs=costs, bottleneck=bott,
                         overlap=overlap)


# ---------------------------------------------------------------------------
# Interleaved (virtual-stage) partition: a device owns V non-contiguous
# layer chunks; chunk v*N + n runs on device n.
# ---------------------------------------------------------------------------

def virtual_cluster(cluster: ClusterSpec, V: int) -> ClusterSpec:
    """Expand an N-device chain into the N*V virtual-stage chain; virtual
    stage i runs on device ``i % N``, so boundary link bandwidths between
    consecutive virtual stages land on the right physical links (including
    the device N-1 -> device 0 wrap links between chunk passes)."""
    if V == 1:
        return cluster
    return ClusterSpec(devices=tuple(
        cluster.devices[i % cluster.n] for i in range(cluster.n * V)))


def interleaved_partition(prof: NetworkProfile, cluster: ClusterSpec,
                          mb: int, V: int, overlap: bool = True,
                          allowed_cuts: Optional[set[int]] = None
                          ) -> PartitionPlan:
    """Balanced partition of L layers into N*V virtual-stage chunks for the
    interleaved ``1F1B-I`` schedule.  Runs the same bottleneck DP over the
    expanded virtual-device chain, then tags the plan with V so device-level
    accessors (``device_costs``/``bottleneck_FB``/``stage_memory``) aggregate
    each device's V chunks."""
    if V == 1:
        return dp_partition(prof, cluster, mb, overlap, allowed_cuts)
    if cluster.n * V > prof.n_layers:
        raise ValueError(f"{cluster.n}x{V} virtual stages exceed "
                         f"{prof.n_layers} layers")
    vcl = virtual_cluster(cluster, V)
    plan = dp_partition(prof, vcl, mb, overlap, allowed_cuts)
    return dataclasses.replace(plan, V=V)


# ---------------------------------------------------------------------------
# Coarse-grained partition based on communication (paper §3.3.3).
# ---------------------------------------------------------------------------

def comm_bound(plan: PartitionPlan) -> bool:
    """Is any stage's boundary transfer longer than its compute?"""
    return any(max(c.comm_in, c.comm_out) * 2 > c.compute()
               for c in plan.stage_costs)


def coarse_cuts(prof: NetworkProfile, a_th: float) -> set[int]:
    """Cut points whose boundary activation is small enough to overlap."""
    return {k for k in range(1, prof.n_layers)
            if prof.layers[k - 1].bytes_act_out <= a_th}


def coarse_partition(prof: NetworkProfile, cluster: ClusterSpec, mb: int,
                     overlap: bool, V: int = 1) -> PartitionPlan:
    """Lower a_th from the max activation until comm is no longer the
    bottleneck (or no finer threshold is feasible).  With ``V > 1`` the
    coarse cuts restrict the interleaved virtual-stage partition instead."""
    sizes = sorted({l.bytes_act_out for l in prof.layers}, reverse=True)
    plan = interleaved_partition(prof, cluster, mb, V, overlap)
    for a_th in sizes:
        cuts = coarse_cuts(prof, a_th)
        if len(cuts) + 1 < cluster.n * V:
            break                              # too coarse to form N*V chunks
        try:
            cand = interleaved_partition(prof, cluster, mb, V, overlap,
                                         allowed_cuts=cuts)
        except ValueError:
            break
        plan = cand
        if not comm_bound(cand):
            return cand
    return plan


# ---------------------------------------------------------------------------
# Intra-layer fractional refinement (paper §3.3.2, FPDeep-style).
# ---------------------------------------------------------------------------

def intra_layer_refine(prof: NetworkProfile, cluster: ClusterSpec,
                       plan: PartitionPlan, mb: int) -> PartitionPlan:
    """Fractionally shift boundary-layer work toward under-loaded
    neighbours.  Analytic (the TPU runtime realises it as tensor-parallel
    sharding of the boundary layer).  Only valid when comm is not the
    bottleneck — intra-layer splits add communication.
    """
    if comm_bound(plan):
        return plan
    times = [c.compute() for c in plan.stage_costs]
    fracs = [0.0] * plan.n_stages
    # smoothing sweeps: move fractions of boundary layers from slower
    # stages to faster neighbours until the bottleneck stops improving
    # (FPDeep's fine-grained workload balancing, applied analytically).
    for _ in range(8 * plan.n_stages):
        before = max(times)
        for n in range(plan.n_stages - 1):
            s, e = plan.bounds[n]
            s2, e2 = plan.bounds[n + 1]
            dev_a, dev_b = cluster.devices[n], cluster.devices[n + 1]
            if times[n] > times[n + 1] and e - s > 1:
                lay = prof.layers[e - 1]
                t_a = fwd_time(lay, dev_a, mb) + bwd_time(lay, dev_a, mb)
                t_b = fwd_time(lay, dev_b, mb) + bwd_time(lay, dev_b, mb)
                # move fraction x: times[n]-x*t_a == times[n+1]+x*t_b
                x = (times[n] - times[n + 1]) / (t_a + t_b)
                x = max(0.0, min(1.0, x))
                times[n] -= x * t_a
                times[n + 1] += x * t_b
                fracs[n] -= x
            elif times[n + 1] > times[n] and e2 - s2 > 1:
                lay = prof.layers[s2]
                t_a = fwd_time(lay, dev_a, mb) + bwd_time(lay, dev_a, mb)
                t_b = fwd_time(lay, dev_b, mb) + bwd_time(lay, dev_b, mb)
                x = (times[n + 1] - times[n]) / (t_a + t_b)
                x = max(0.0, min(1.0, x))
                times[n + 1] -= x * t_b
                times[n] += x * t_a
                fracs[n] += x
        if max(times) > before - 1e-12:
            break
    new_bott = max(max(t, 2 * max(c.comm_in, c.comm_out))
                   if plan.overlap else t + 2 * max(c.comm_in, c.comm_out)
                   for t, c in zip(times, plan.stage_costs))
    # scale each stage's (fwd, bwd) to the refined compute total so the
    # schedule evaluator sees post-refinement bottleneck times (the B/W
    # split scales with the bwd it was profiled from)
    new_costs = tuple(
        dataclasses.replace(c, fwd=c.fwd * (t / c.compute()),
                            bwd=c.bwd * (t / c.compute()),
                            bwd_w=c.bwd_w * (t / c.compute()))
        if c.compute() > 0 else c
        for t, c in zip(times, plan.stage_costs))
    return dataclasses.replace(plan, frac_shift=tuple(fracs),
                               stage_costs=new_costs,
                               bottleneck=min(plan.bottleneck, new_bott))


# ---------------------------------------------------------------------------
# Memory fine-tuning (paper §3.3, final step).
# ---------------------------------------------------------------------------

def stage_memory(plan: PartitionPlan, feat_mult: int, M: int,
                 schedule: Optional[str] = None,
                 mem_limit=None) -> list[float]:
    """Schedule-dependent per-device memory: 2w (weights+grads) plus the
    live micro-batch boundary activations.  The live counts come from the
    schedule-plan IR (:func:`repro.core.schedplan.live_activation_counts`,
    the algebraic form of the op-table replay): feat_mult*(N-i+1) for the
    contiguous schedules, ``(V-1)*M + N - i + 1`` chunk activations for a
    streaming interleaved plan, ``2(N-i) + (V-1)N + 1`` for the memory-lean
    interleaved order, the zero-bubble rows for the ``zb-*`` family
    (``mem_limit`` caps the zb-auto row; unbounded zb-auto pays M).
    ``schedule`` defaults to the plan's natural schedule (1F1B for
    V == 1, streaming 1F1B-I for V > 1)."""
    from repro.core.schedplan import live_activation_counts
    N = plan.n_stages
    if schedule is None:
        schedule = "1f1b" if plan.V == 1 else "1f1b-interleaved"
    live = live_activation_counts(schedule, M, N, plan.V, feat_mult,
                                  mem_limit=mem_limit)
    return [2.0 * c.weight_bytes + lv * c.act_out_bytes
            for lv, c in zip(live, plan.device_costs())]


def memory_fine_tune(prof: NetworkProfile, cluster: ClusterSpec,
                     plan: PartitionPlan, mb: int, feat_mult: int,
                     M: int, max_iters: int = 64,
                     schedule: Optional[str] = None,
                     mem_limit=None
                     ) -> tuple[PartitionPlan, bool]:
    """Shift boundary layers off over-capacity devices.  Returns
    (plan, feasible).  ``schedule`` picks the live-activation row used to
    judge capacity (defaults to the plan's natural schedule).  For an
    interleaved plan (V > 1) memory is judged per device but layers move
    across *chunk* boundaries, so the donor chunk's neighbour belongs to a
    different device."""
    V = plan.V
    vcl = virtual_cluster(cluster, V)
    bounds = [list(b) for b in plan.bounds]
    N = plan.n_stages
    NC = len(bounds)                           # chunks = N*V

    def finalize() -> PartitionPlan:
        cur = _finalize(prof, vcl, tuple(tuple(b) for b in bounds), mb,
                        plan.overlap)
        return dataclasses.replace(cur, V=V) if V > 1 else cur

    for _ in range(max_iters):
        cur = finalize()
        mem = stage_memory(cur, feat_mult, M, schedule, mem_limit)
        caps = [d.memory_capacity for d in cluster.devices]
        over = [i for i in range(N) if mem[i] > caps[i]]
        if not over:
            return cur, True
        moved = False
        for i in over:
            # candidate donations: last layer of a chunk to the next chunk,
            # or first layer to the previous chunk; judged by the headroom
            # of the *device* that owns the receiving chunk.
            best = None                        # (headroom, chunk, dir)
            for j in cur.device_chunks(i):
                s, e = bounds[j]
                if e - s <= 1:
                    continue
                if j < NC - 1:
                    tgt = (j + 1) % N
                    head = caps[tgt] - mem[tgt]
                    if best is None or head >= best[0]:
                        best = (head, j, +1)
                if j > 0:
                    tgt = (j - 1) % N
                    head = caps[tgt] - mem[tgt]
                    if best is None or head > best[0]:
                        best = (head, j, -1)
            if best is None:
                continue
            _, j, d = best
            if d > 0:
                bounds[j][1] -= 1
                bounds[j + 1][0] -= 1
            else:
                bounds[j][0] += 1
                bounds[j - 1][1] += 1
            moved = True
        if not moved:
            return cur, False
    cur = finalize()
    mem = stage_memory(cur, feat_mult, M, schedule, mem_limit)
    ok = all(m <= d.memory_capacity for m, d in zip(mem, cluster.devices))
    return cur, ok


# ---------------------------------------------------------------------------
# 3D stage costing: per-stage (dp, tp) shards over a device pool.
#
# The 1D partitioner above balances layers across a FIXED device chain.
# The 3D explorer instead hands each pipeline stage a (dp, tp) chip
# grid carved from a FleetSpec pool: dp replicas each see mb/dp of the
# micro-batch, tp shards split every GEMM 1/tp at the price of the
# per-layer tensor collective.  The functions below turn one such
# assignment into the same first-class StageCosts vector the builders,
# simulator and eval_*_hetero forms already consume — width is priced
# INTO the durations, the `width` field is annotation only.
# ---------------------------------------------------------------------------

def reshard_sr(act_bytes: float, shard_a: tuple[int, int],
               shard_b: tuple[int, int], bandwidth: float) -> float:
    """Boundary transfer time between adjacent stages sharded
    ``shard_a = (dp_a, tp_a)`` and ``shard_b = (dp_b, tp_b)``.

    When the layouts agree, each of the ``min(tp)`` aligned link pairs
    carries its own 1/tp activation slice concurrently — the transfer
    rides ``min(tp_a, tp_b)`` links.  When they differ (a boundary
    RESHARD), the activation must additionally be regathered and
    resliced to the consumer's grid — charged as one extra
    full-activation pass over a single link, the conservative
    store-and-forward bound."""
    if act_bytes <= 0.0:
        return 0.0
    base = act_bytes / (min(shard_a[1], shard_b[1]) * bandwidth)
    if tuple(shard_a) != tuple(shard_b):
        base += act_bytes / bandwidth
    return base


def plan_costs_3d(prof: NetworkProfile, dev: DeviceSpec,
                  bounds, mb: int, shards,
                  include_embed_head: bool = True):
    """Cost a layer partition under per-stage (dp, tp) shards.

    ``bounds`` is the per-stage [start, end) layer ranges, ``shards``
    one ``(dp, tp)`` pair per stage, ``dev`` the (homogeneous) pool's
    base chip.  Each stage's dp replicas process ``mb / dp`` of the
    micro-batch; its GEMMs shard 1/tp with the Megatron collective
    priced at the chip's ``tensor`` axis bandwidth
    (:func:`repro.core.profiler.fwd_time_tp`); stage hops pay the
    :func:`reshard_sr` boundary term at the ``stage`` axis bandwidth.
    Returns :class:`repro.core.schedplan.StageCosts` with the
    ``width = dp*tp`` annotation."""
    from repro.core.schedplan import StageCosts
    bounds = [tuple(b) for b in bounds]
    shards = [(int(d), int(t)) for d, t in shards]
    if len(bounds) != len(shards):
        raise ValueError(f"{len(bounds)} stages but {len(shards)} shards")
    if any(d < 1 or t < 1 for d, t in shards):
        raise ValueError(f"shards must be >= (1, 1), got {shards}")
    N = len(bounds)
    F, B, W = [], [], []
    for i, ((s, e), (dp, tp)) in enumerate(zip(bounds, shards)):
        units = mb / dp
        lays = [prof.layers[k] for k in range(s, e)]
        if include_embed_head:
            if i == 0 and prof.embed is not None:
                lays.append(prof.embed)
            if i == N - 1 and prof.head is not None:
                lays.append(prof.head)
        f = b = w = 0.0
        for lay in lays:
            f += fwd_time_tp(lay, dev, units, tp)
            bi, wi = bwd_split_time_tp(lay, dev, units, tp)
            b += bi
            w += wi
        F.append(f)
        B.append(b)
        W.append(w)
    bw = dev.axis_bandwidth("stage")
    SR = tuple(
        reshard_sr(prof.layers[bounds[i][1] - 1].bytes_act_out * mb,
                   shards[i], shards[i + 1], bw)
        for i in range(N - 1))
    eps = max(max(F + B + W, default=1.0), 1.0) * 1e-12
    return StageCosts(
        F=tuple(max(f, eps) for f in F),
        B=tuple(max(b, eps) for b in B),
        W=tuple(max(w, eps) for w in W),
        SR=SR,
        width=tuple(d * t for d, t in shards))


def stage_memory_3d(prof: NetworkProfile, bounds, shards, mb: int,
                    live=None, include_embed_head: bool = True
                    ) -> list[float]:
    """Per-CHIP memory of each 3D stage: weights+grads shard 1/tp
    (Megatron splits the parameter matrices), live boundary
    activations shard across BOTH axes (each chip holds ``mb/dp``
    samples of a 1/tp hidden slice) — the 'fat stages buy width' lever.
    ``live`` is the per-stage live-activation count (default the 1F1B
    ``N - i`` ramp)."""
    N = len(bounds)
    if live is None:
        live = [N - i for i in range(N)]
    out = []
    for i, ((s, e), (dp, tp)) in enumerate(zip(bounds, shards)):
        wbytes = sum(prof.layers[k].bytes_weights for k in range(s, e))
        if include_embed_head:
            if i == 0 and prof.embed is not None:
                wbytes += prof.embed.bytes_weights
            if i == N - 1 and prof.head is not None:
                wbytes += prof.head.bytes_weights
        act = prof.layers[e - 1].bytes_act_out * mb if e - 1 < prof.n_layers \
            else 0.0
        out.append(2.0 * wbytes / tp + live[i] * act / (dp * tp))
    return out
