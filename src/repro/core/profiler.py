"""DNN profiling for BaPipe.

The paper profiles every layer of the network to obtain (a) FP/BP compute
time per accelerator type, (b) weights size, (c) output-feature size
(paper Fig. 3, "DNN profile").  On GPU clusters it measures a 1000-minibatch
run; for FPGA clusters it *derives* the profile analytically from the DNN
configuration and the hardware constraints.  We take the analytic route for
the TPU target (same approach as the paper's FPGA simulator) and expose a
measured mode for CPU-runnable reduced models.

Units: ``flops_*``  are FLOPs per *unit* (one token for sequence models, one
sample for conv nets); ``bytes_*`` are bytes at the profile dtype.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

from repro.core.hardware import DeviceSpec
from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    name: str
    flops_fwd: float            # per unit
    bytes_weights: float        # parameter bytes
    bytes_act_out: float        # boundary activation bytes per unit
    flops_bwd: float = 0.0      # default: 2x fwd (dL/dx and dL/dw matmuls)
    # Fraction of the backward spent in the weight-gradient (W) half —
    # the zero-bubble split the cost-shaped schedules consume.  0.5 is
    # the pure-weight-matmul point (dL/dx and dL/dw are the same GEMM
    # transposed); attention/scan work has no dL/dw, so its layers sit
    # below 0.5.  Analytic by default; :func:`measure_w_frac` measures
    # it from real vjp timings on a representative layer.
    w_frac: float = 0.5

    def __post_init__(self):
        if self.flops_bwd == 0.0:
            object.__setattr__(self, "flops_bwd", 2.0 * self.flops_fwd)
        if not 0.0 < self.w_frac < 1.0:
            raise ValueError(f"w_frac must be in (0, 1), got {self.w_frac}")


@dataclasses.dataclass(frozen=True)
class NetworkProfile:
    """Per-layer profile of a network, at a fixed sequence length."""

    name: str
    layers: tuple[LayerProfile, ...]
    unit: str                   # "token" | "sample"
    bytes_per_param: int = 2    # bf16
    # embed / head live outside the partitioned layer sequence but count
    # toward stage-0 / stage-(N-1) load and memory.
    embed: LayerProfile | None = None
    head: LayerProfile | None = None

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def total_flops_fwd(self) -> float:
        return sum(l.flops_fwd for l in self.layers)

    def total_bytes_weights(self) -> float:
        return sum(l.bytes_weights for l in self.layers)


# ---------------------------------------------------------------------------
# Analytic time model (per micro-batch of ``units`` tokens/samples).
# ---------------------------------------------------------------------------

def fwd_time(layer: LayerProfile, dev: DeviceSpec, units: int) -> float:
    """Roofline per layer: compute-bound or weight-streaming-bound."""
    compute = units * layer.flops_fwd / dev.effective_flops
    memory = layer.bytes_weights / dev.hbm_bandwidth
    return max(compute, memory)


def bwd_time(layer: LayerProfile, dev: DeviceSpec, units: int) -> float:
    compute = units * layer.flops_bwd / dev.effective_flops
    memory = 2.0 * layer.bytes_weights / dev.hbm_bandwidth   # read W, write dW
    return max(compute, memory)


def bwd_split_time(layer: LayerProfile, dev: DeviceSpec,
                   units: int) -> tuple[float, float]:
    """(input-gradient, weight-gradient) split of :func:`bwd_time` by
    the layer's ``w_frac`` — the per-layer form of the zero-bubble B/W
    durations the cost-shaped schedules consume."""
    t = bwd_time(layer, dev, units)
    return t * (1.0 - layer.w_frac), t * layer.w_frac


def comm_time(act_bytes: float, link_bandwidth: float) -> float:
    return act_bytes / link_bandwidth


# Megatron-style tensor parallelism all-reduces the block's activation
# twice per forward pass (attention out-proj + MLP out-proj) and twice
# per backward pass; each is a ring AR of the boundary activation over
# the stage's tp chips on the ``tensor`` axis.
TP_COLLECTIVES_FWD = 2
TP_COLLECTIVES_BWD = 2


def tp_collective_time(layer: LayerProfile, dev: DeviceSpec, units: int,
                       tp: int, n_collectives: int = TP_COLLECTIVES_FWD
                       ) -> float:
    """Per-micro-batch tensor-parallel collective cost of one layer:
    ``n_collectives`` ring all-reduces of the layer's activation over
    ``tp`` chips, priced at the device's ``tensor`` axis bandwidth —
    NOT the stage link (see :meth:`DeviceSpec.axis_bandwidth`)."""
    if tp <= 1:
        return 0.0
    bw = dev.axis_bandwidth("tensor")
    return n_collectives * 2.0 * (tp - 1) / tp \
        * units * layer.bytes_act_out / bw


def fwd_time_tp(layer: LayerProfile, dev: DeviceSpec, units: int,
                tp: int) -> float:
    """TP-sharded forward roofline: flops and weight streaming both
    shard 1/tp (Megatron column/row splits), plus the per-layer TP
    collective — the explicit price of buying width."""
    if tp <= 1:
        return fwd_time(layer, dev, units)
    compute = units * layer.flops_fwd / tp / dev.effective_flops
    memory = layer.bytes_weights / tp / dev.hbm_bandwidth
    return max(compute, memory) \
        + tp_collective_time(layer, dev, units, tp, TP_COLLECTIVES_FWD)


def bwd_time_tp(layer: LayerProfile, dev: DeviceSpec, units: int,
                tp: int) -> float:
    if tp <= 1:
        return bwd_time(layer, dev, units)
    compute = units * layer.flops_bwd / tp / dev.effective_flops
    memory = 2.0 * layer.bytes_weights / tp / dev.hbm_bandwidth
    return max(compute, memory) \
        + tp_collective_time(layer, dev, units, tp, TP_COLLECTIVES_BWD)


def bwd_split_time_tp(layer: LayerProfile, dev: DeviceSpec, units: int,
                      tp: int) -> tuple[float, float]:
    """(input-gradient, weight-gradient) split of :func:`bwd_time_tp`.
    The backward's TP collectives sit on the input-gradient (B) half —
    dL/dx is what crosses the shards; dL/dw is shard-local — so the
    collective term lands on B, keeping W a pure local GEMM the
    zero-bubble schedules can float freely."""
    if tp <= 1:
        return bwd_split_time(layer, dev, units)
    compute = units * layer.flops_bwd / tp / dev.effective_flops
    memory = 2.0 * layer.bytes_weights / tp / dev.hbm_bandwidth
    t = max(compute, memory)
    coll = tp_collective_time(layer, dev, units, tp, TP_COLLECTIVES_BWD)
    return t * (1.0 - layer.w_frac) + coll, t * layer.w_frac


# ---------------------------------------------------------------------------
# Transformer-family analytic profiles (the 10 assigned architectures).
# ---------------------------------------------------------------------------

def _attn_flops(cfg: ArchConfig, seq: int,
                layer_idx: int) -> tuple[float, float, float]:
    """(flops_per_token, weight_params, weight_matmul_flops) for the
    attention sub-block — the third element is the projection share
    (flops with a dL/dw counterpart; the QK^T/PV span work has none)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    # effective attended length (causal average; window caps it)
    span = seq / 2
    if cfg.window > 0 and not cfg.is_global_layer(layer_idx):
        span = min(span, cfg.window)
    if cfg.attn_kind == "mla":
        m = cfg.mla
        qk_dim = m.qk_nope_dim + m.qk_rope_dim
        w = 0.0
        if m.q_lora_rank:
            w += d * m.q_lora_rank + m.q_lora_rank * nh * qk_dim
        else:
            w += d * nh * qk_dim
        w += d * (m.kv_lora_rank + m.qk_rope_dim)
        w += m.kv_lora_rank * nh * (m.qk_nope_dim + m.v_head_dim)
        w += nh * m.v_head_dim * d
        proj_flops = 2.0 * w
        attn_flops = 2.0 * span * nh * (qk_dim + m.v_head_dim)
        return proj_flops + attn_flops, w, proj_flops
    else:
        w = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        proj_flops = 2.0 * w
        attn_flops = 2.0 * span * nh * hd * 2     # QK^T and PV
        return proj_flops + attn_flops, w, proj_flops


def _ffn_flops(cfg: ArchConfig,
               layer_idx: int) -> tuple[float, float, float]:
    d = cfg.d_model
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        m = cfg.moe
        w_active = (m.n_shared + m.top_k) * 3 * d * m.d_ff_expert + d * m.n_routed
        w_total = (m.n_shared + m.n_routed) * 3 * d * m.d_ff_expert + d * m.n_routed
        return 2.0 * w_active, w_total, 2.0 * w_active
    w = 3 * d * cfg.d_ff
    return 2.0 * w, w, 2.0 * w


def _ssm_flops(cfg: ArchConfig) -> tuple[float, float, float]:
    d = cfg.d_model
    s = cfg.ssm
    d_inner = s.expand * d
    nh = s.n_heads(d)
    w = (d * (2 * d_inner + 2 * s.d_state + nh)   # in_proj (x,z,B,C,dt)
         + s.d_conv * (d_inner + 2 * s.d_state)   # conv1d
         + d_inner * d)                            # out_proj
    proj = 2.0 * w
    scan = 6.0 * d_inner * s.d_state               # state update + readout
    return proj + scan, w, proj


def _analytic_w_frac(flops_fwd: float, flops_wgrad: float) -> float:
    """Weight-gradient share of the backward from the analytic model:
    the backward is 2x the forward, of which the dL/dw GEMMs redo
    exactly the weight-matmul share of the forward (attention/scan work
    has no weight gradient)."""
    if flops_fwd <= 0:
        return 0.5
    return min(0.95, max(0.05, 0.5 * flops_wgrad / flops_fwd))


def layer_kind(cfg: ArchConfig, layer_idx: int) -> str:
    """Timing kind of layer ``layer_idx`` for the measured B/W split:
    ``"moe"`` for expert-FFN layers (past ``first_k_dense``), ``"ssm"``
    for pure state-space trunks (associative-scan recurrence in place of
    attention), ``"dense"`` otherwise.  Hybrid trunks time as
    ``"dense"`` — their attention dominates the no-dL/dw share and the
    dense proxy's softmax term stands in for the scan."""
    if cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense:
        return "moe"
    if cfg.family == "ssm":
        return "ssm"
    return "dense"


def profile_arch(cfg: ArchConfig, seq: int = 4096) -> NetworkProfile:
    """Analytic per-layer profile at sequence length ``seq``.

    Each layer carries its B/W backward split (``LayerProfile.w_frac``):
    analytic by default (weight-matmul share of the layer's flops), or —
    when ``cfg.profile_w_frac == "measured"`` — measured from real vjp
    timings of one representative layer PER DISTINCT LAYER KIND
    (:func:`measure_w_frac`; a mixed dense+MoE trunk times both proxies,
    where it used to time one block and smear its split over every
    layer), falling back to the per-layer analytic split for any kind
    whose timing is unavailable."""
    bpp = 2
    d = cfg.d_model
    act_out = float(d * bpp)
    if cfg.profile_w_frac not in ("analytic", "measured"):
        raise ValueError(f"profile_w_frac must be 'analytic' or "
                         f"'measured', got {cfg.profile_w_frac!r}")
    measured: dict[str, float | None] = {}
    if cfg.profile_w_frac == "measured":
        kinds = {layer_kind(cfg, i) for i in range(cfg.n_layers)}
        measured = {k: measure_w_frac(cfg, seq=min(seq, 128), kind=k)
                    for k in sorted(kinds)}
    layers = []
    for i in range(cfg.n_layers):
        f, w, fw = 0.0, 0.0, 0.0
        is_enc = i < cfg.n_enc_layers
        if cfg.family == "ssm":
            fs, ws, ps = _ssm_flops(cfg)
            f, w, fw = f + fs, w + ws, fw + ps
        else:
            if cfg.attn_kind != "none":
                fa, wa, pa = _attn_flops(cfg, seq, i)
                f, w, fw = f + fa, w + wa, fw + pa
            if cfg.family == "hybrid":
                fs, ws, ps = _ssm_flops(cfg)
                f, w, fw = f + fs, w + ws, fw + ps
            if cfg.n_enc_layers and not is_enc:
                # decoder cross-attention over encoder frames
                fa, wa, pa = _attn_flops(cfg, seq, i)
                f, w, fw = f + fa, w + wa, fw + pa
        ff, wf_, pf = _ffn_flops(cfg, i)
        f, w, fw = f + ff, w + wf_, fw + pf
        # norms etc: negligible flops, tiny weights
        w += 2 * d
        wf_meas = measured.get(layer_kind(cfg, i))
        layers.append(LayerProfile(
            name=f"{cfg.arch_id}.L{i}", flops_fwd=f,
            bytes_weights=w * bpp, bytes_act_out=act_out,
            w_frac=wf_meas if wf_meas is not None
            else _analytic_w_frac(f, fw)))
    embed = LayerProfile(name="embed", flops_fwd=0.0,
                         bytes_weights=float(cfg.vocab * d * bpp),
                         bytes_act_out=act_out)
    head = LayerProfile(name="lm_head", flops_fwd=2.0 * d * cfg.vocab,
                        bytes_weights=0.0 if cfg.tie_embeddings
                        else float(cfg.vocab * d * bpp),
                        bytes_act_out=float(cfg.vocab * bpp))
    return NetworkProfile(name=cfg.arch_id, layers=tuple(layers),
                          unit="token", bytes_per_param=bpp,
                          embed=embed, head=head)


# ---------------------------------------------------------------------------
# The paper's own models (per-sample profiles) — feed the Table 3/4/6 benches.
# ---------------------------------------------------------------------------

_VGG16_CONV = [
    # (out_ch, spatial, in_ch)   224x224 ImageNet
    (64, 224, 3), (64, 224, 64),
    (128, 112, 64), (128, 112, 128),
    (256, 56, 128), (256, 56, 256), (256, 56, 256),
    (512, 28, 256), (512, 28, 512), (512, 28, 512),
    (512, 14, 512), (512, 14, 512), (512, 14, 512),
]


def profile_vgg16(bpp: int = 2) -> NetworkProfile:
    layers = []
    for i, (oc, sp, ic) in enumerate(_VGG16_CONV):
        w = 3 * 3 * ic * oc
        f = 2.0 * w * sp * sp
        layers.append(LayerProfile(
            name=f"conv{i}", flops_fwd=f, bytes_weights=w * bpp,
            bytes_act_out=float(oc * (sp // (2 if i in (1, 3, 6, 9) else 1)) ** 2 * bpp)))
    fcs = [(7 * 7 * 512, 4096), (4096, 4096), (4096, 1000)]
    for i, (fi, fo) in enumerate(fcs):
        layers.append(LayerProfile(
            name=f"fc{i}", flops_fwd=2.0 * fi * fo,
            bytes_weights=float(fi * fo * bpp), bytes_act_out=float(fo * bpp)))
    return NetworkProfile("vgg16", tuple(layers), unit="sample",
                          bytes_per_param=bpp)


_RESNET50_STAGES = [  # (n_blocks, width, spatial)
    (3, 64, 56), (4, 128, 28), (6, 256, 14), (3, 512, 7)]


def profile_resnet50(bpp: int = 2) -> NetworkProfile:
    layers = [LayerProfile("stem", flops_fwd=2.0 * 7 * 7 * 3 * 64 * 112 * 112,
                           bytes_weights=7 * 7 * 3 * 64 * bpp,
                           bytes_act_out=float(64 * 56 * 56 * bpp))]
    in_ch = 64
    for (n, w, sp) in _RESNET50_STAGES:
        for b in range(n):
            c_out = w * 4
            wts = in_ch * w + 3 * 3 * w * w + w * c_out
            if b == 0:
                wts += in_ch * c_out   # projection shortcut
            f = 2.0 * wts * sp * sp
            layers.append(LayerProfile(
                name=f"res{w}_{b}", flops_fwd=f, bytes_weights=wts * bpp,
                bytes_act_out=float(c_out * sp * sp * bpp)))
            in_ch = c_out
    layers.append(LayerProfile("fc", flops_fwd=2.0 * 2048 * 1000,
                               bytes_weights=2048 * 1000 * bpp,
                               bytes_act_out=1000.0 * bpp))
    return NetworkProfile("resnet50", tuple(layers), unit="sample",
                          bytes_per_param=bpp)


def profile_gnmt(n_lstm: int = 8, d: int = 1024, seq: int = 50,
                 vocab: int = 32000, bpp: int = 2) -> NetworkProfile:
    """GNMT: n_lstm/2 encoder + n_lstm/2 decoder LSTM layers (+attention)."""
    layers = []
    per_lstm_w = 4 * (d * d + d * d)          # input + recurrent gates
    per_lstm_f = 2.0 * per_lstm_w * seq       # per sample (seq tokens)
    for i in range(n_lstm):
        half = n_lstm // 2
        name = f"enc{i}" if i < half else f"dec{i - half}"
        f, w = per_lstm_f, per_lstm_w
        if i == half:                          # decoder attention layer
            w += 2 * d * d
            f += 2.0 * (2 * d * d) * seq + 2.0 * seq * seq * d
        layers.append(LayerProfile(
            name=name, flops_fwd=f, bytes_weights=float(w * bpp),
            bytes_act_out=float(d * seq * bpp)))
    layers.append(LayerProfile(
        "softmax", flops_fwd=2.0 * d * vocab * seq,
        bytes_weights=float(d * vocab * bpp),
        bytes_act_out=float(vocab * bpp)))
    return NetworkProfile(f"gnmt-{n_lstm}", tuple(layers), unit="sample",
                          bytes_per_param=bpp)


def profile_gnmt_L(n_lstm: int) -> NetworkProfile:
    """GNMT-L of paper Table 4: stacked L/2 encoder + L/2 decoder layers."""
    return profile_gnmt(n_lstm=n_lstm)


# ---------------------------------------------------------------------------
# Measured profiling (CPU-runnable reduced models) — paper's GPU mode.
# ---------------------------------------------------------------------------

def measure_layer(fn: Callable, *args, iters: int = 5) -> float:
    """Median wall-time of a jitted callable (CPU measured mode)."""
    import jax
    fn_j = jax.jit(fn)
    out = fn_j(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn_j(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def measure_w_frac(cfg: ArchConfig, seq: int = 128, iters: int = 5,
                   kind: str = "dense") -> float | None:
    """Measure the backward's B/W split from real vjp timings on ONE
    representative layer of ``cfg`` (reduced dims, CPU-runnable): a
    transformer-block proxy with the config's projection GEMMs plus a
    softmax-attention term (work with no weight gradient), closed by
    the FFN of the requested ``kind`` — the dense up/down GEMMs, or
    (``kind="moe"``) a router GEMM plus the config's shared + top-k
    expert GEMMs run under dense routing with top-k gate masking (every
    expert executes, gates zero the unpicked ones — the static-shape
    timing proxy for token dropping-free MoE).  ``kind="ssm"`` swaps
    the attention for the state-space mixer: in/out projection GEMMs
    around a gated linear recurrence run as a
    ``jax.lax.associative_scan`` — the scan combine holds no
    parameters, so like the softmax span work its vjp has no dL/dw and
    only the projections contribute to the W half.  The full vjp
    computes both cotangents; the input-only vjp (parameters closed
    over) skips every dL/dw GEMM — the timing excess is the
    weight-gradient share.

    Returns ``w_frac`` in (0, 1), or ``None`` when timing is
    unavailable or degenerate (no jax, ``kind="moe"`` without an MoE
    config, ``kind="ssm"`` without an SSM config, or noise pushes the
    ratio out of (0.02, 0.98)) — callers fall back to the per-layer
    analytic split."""
    try:
        import jax  # noqa: F401
    except Exception:
        return None
    if kind not in ("dense", "moe", "ssm"):
        raise ValueError(f"kind must be 'dense', 'moe' or 'ssm', "
                         f"got {kind!r}")
    if kind == "moe" and cfg.moe is None:
        return None
    if kind == "ssm" and cfg.ssm is None:
        return None
    try:
        import jax

        p0, x, ct, block = _block_proxy(cfg, seq, kind)

        def vjp_full(p, x, ct):
            return jax.vjp(block, p, x)[1](ct)

        def vjp_input_only(x, ct):
            return jax.vjp(lambda xx: block(p0, xx), x)[1](ct)

        t_full = measure_layer(vjp_full, p0, x, ct, iters=iters)
        t_x = measure_layer(vjp_input_only, x, ct, iters=iters)
        if t_full <= 0:
            return None
        wf = (t_full - t_x) / t_full
        if not 0.02 < wf < 0.98:
            return None
        return wf
    except Exception:
        return None


def _block_proxy(cfg: ArchConfig, seq: int, kind: str):
    """Build the reduced, CPU-runnable transformer-block proxy of
    ``kind`` (the layer :func:`measure_w_frac` documents) and return
    ``(params, x, cotangent, block_fn)`` — shared by the W-split and
    per-stage live timers."""
    import jax
    import jax.numpy as jnp

    d = max(32, min(cfg.d_model, 256))
    ff = max(2 * d, min(cfg.d_ff or 4 * d, 4 * d))
    seq = max(8, min(seq, 256))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 10)
    scale = 1.0 / math.sqrt(d)
    if kind == "ssm":
        s_ = cfg.ssm
        di = max(d, min(s_.expand * d, 2 * d))
        p0 = {"w_in": jax.random.normal(ks[0], (d, 3 * di)) * scale,
              "w_out": jax.random.normal(ks[3], (di, d)) * scale}

        def mix(p, x):
            xi, a_raw, z = jnp.split(x @ p["w_in"], 3, axis=-1)
            a = jax.nn.sigmoid(a_raw)      # decay in (0, 1)

            def comb(l, r):
                # h_t = a_t * h_{t-1} + x_t as a monoid over
                # (decay, state) pairs — parameter-free, so its
                # vjp contributes only to the B (input-grad) half
                return (l[0] * r[0], r[0] * l[1] + r[1])

            _, h = jax.lax.associative_scan(comb, (a, xi), axis=0)
            return (h * jax.nn.silu(z)) @ p["w_out"]
    else:
        p0 = {"wq": jax.random.normal(ks[0], (d, d)) * scale,
              "wk": jax.random.normal(ks[1], (d, d)) * scale,
              "wv": jax.random.normal(ks[2], (d, d)) * scale,
              "wo": jax.random.normal(ks[3], (d, d)) * scale}

        def mix(p, x):
            q, k, v = x @ p["wq"], x @ p["wk"], x @ p["wv"]
            s = jax.nn.softmax(q @ k.T * scale, axis=-1)
            return (s @ v) @ p["wo"]
    if kind == "moe":
        m = cfg.moe
        ne = max(2, min(4, m.n_shared + m.n_routed))
        tk = max(1, min(m.top_k, ne))
        fe = max(16, min(m.d_ff_expert, d))
        p0.update(
            wr=jax.random.normal(ks[4], (d, ne)) * scale,
            we1=jax.random.normal(ks[5], (ne, d, fe)) * scale,
            we2=jax.random.normal(ks[6], (ne, fe, d)) * scale)

        def ffn(p, h):
            gates = jax.nn.softmax(h @ p["wr"], axis=-1)
            kth = jnp.sort(gates, axis=-1)[:, -tk][:, None]
            gates = jnp.where(gates >= kth, gates, 0.0)
            y = jax.nn.silu(jnp.einsum("sd,edf->esf", h, p["we1"]))
            y = jnp.einsum("esf,efd->esd", y, p["we2"])
            return jnp.einsum("se,esd->sd", gates, y)
    elif kind == "ssm" and not cfg.d_ff:
        # pure-Mamba blocks are mixer-only (no FFN)
        def ffn(p, h):
            return h
    else:
        p0.update(w1=jax.random.normal(ks[4], (d, ff)) * scale,
                  w2=jax.random.normal(ks[5], (ff, d)) * scale)

        def ffn(p, h):
            return jax.nn.silu(h @ p["w1"]) @ p["w2"]

    x = jax.random.normal(ks[7], (seq, d))

    def block(p, x):
        return ffn(p, mix(p, x))

    ct = jnp.ones((seq, d))
    return p0, x, ct, block


def measure_block_time(cfg: ArchConfig, seq: int = 64, iters: int = 3,
                       kind: str = "dense") -> float | None:
    """Median wall-time of ONE full vjp (forward + both cotangents)
    through the reduced block proxy of ``kind`` — the live-timing
    primitive behind :func:`measure_stage_times`.  Returns ``None``
    when timing is unavailable (no jax, ``kind`` has no matching
    config) — callers fall back to the analytic cost vector."""
    try:
        import jax  # noqa: F401
    except Exception:
        return None
    if kind not in ("dense", "moe", "ssm"):
        raise ValueError(f"kind must be 'dense', 'moe' or 'ssm', "
                         f"got {kind!r}")
    if kind == "moe" and cfg.moe is None:
        return None
    if kind == "ssm" and cfg.ssm is None:
        return None
    try:
        import jax

        p0, x, ct, block = _block_proxy(cfg, seq, kind)

        def vjp_full(p, x, ct):
            return jax.vjp(block, p, x)[1](ct)

        t = measure_layer(vjp_full, p0, x, ct, iters=iters)
        return t if t > 0 else None
    except Exception:
        return None


def stage_layer_kinds(cfg: ArchConfig, plan) -> list[list[str]]:
    """Per-stage list of the timing kinds of the REAL layers each stage
    owns under ``plan`` (a :class:`~repro.pipeline.stage.StagePlan` or
    anything with ``n_stages``/``virtual``/``layers_per_stage``),
    following the Megatron chunk placement (chunk ``v*S + n`` lives on
    device ``n``).  Padded slots are inactive and excluded."""
    S, V, Lc = plan.n_stages, plan.virtual, plan.layers_per_stage
    out = []
    for n in range(S):
        kinds = []
        for v in range(V):
            chunk = v * S + n
            for l in range(chunk * Lc, (chunk + 1) * Lc):
                if l < cfg.n_layers:
                    kinds.append(layer_kind(cfg, l))
        out.append(kinds)
    return out


def measure_stage_times(cfg: ArchConfig, plan, seq: int = 64,
                        iters: int = 3) -> list[float] | None:
    """Measured per-stage step-time vector for ``plan``: time one
    reduced block proxy per DISTINCT layer kind in the trunk
    (:func:`measure_block_time`) and charge each stage the sum over the
    real layers it owns.  This is the live side of the drift monitor —
    on a shared host every stage's layers run on the same silicon, so
    one proxy timing per kind is exact up to layer-count weighting;
    on a real fleet each stage would time its own step and the vector
    arrives from the collective instead.  Returns ``None`` when any
    needed proxy timing is unavailable."""
    per_stage = stage_layer_kinds(cfg, plan)
    kinds = sorted({k for ks in per_stage for k in ks})
    t = {k: measure_block_time(cfg, seq=seq, iters=iters, kind=k)
         for k in kinds}
    if any(t[k] is None for k in kinds):
        return None
    return [sum(t[k] for k in ks) for ks in per_stage]


# ---------------------------------------------------------------------------
# Drift monitoring — live step timings vs the planned cost vector.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DriftMonitor:
    """EMA of measured per-stage step timings, compared against the
    partition plan's predicted cost vector.

    Both vectors are normalised to *shares* of their own total before
    comparison, so the metric is invariant to absolute scale — a CPU
    host running 1000x slower than the modelled TPU shows zero drift as
    long as the stages stay in the planned ratio.  Drift is the worst
    per-stage relative share error::

        drift = max_n |m_n - p_n| / p_n      (m, p = measured/planned shares)

    ``should_replan()`` goes true once the EMA has absorbed
    ``min_samples`` updates AND drift exceeds ``threshold`` (default
    0.25: some stage is doing 25% more or less than its planned share
    of the work — the balance the partition was chosen for is gone).

    ``slowdown()`` reports the per-stage measured/planned share ratio —
    the derating vector :func:`repro.core.autoplan.replan` feeds back
    into the cost model so the re-search sees the skewed fleet."""

    planned: tuple[float, ...]
    alpha: float = 0.25              # EMA weight of the newest sample
    threshold: float = 0.25
    min_samples: int = 3
    ema: Optional[list[float]] = None
    n_samples: int = 0

    def __post_init__(self):
        if len(self.planned) < 1 or any(p <= 0 for p in self.planned):
            raise ValueError(f"planned stage costs must be positive, "
                             f"got {self.planned}")

    @classmethod
    def from_plan(cls, plan, **kw) -> "DriftMonitor":
        """Build from a :class:`~repro.core.partition.PartitionPlan`:
        the planned per-stage cost is F + B + W of its cost vector."""
        c = plan.cost_vector()
        planned = tuple(f + b + w for f, b, w in zip(c.F, c.B, c.W))
        return cls(planned=planned, **kw)

    def update(self, measured: Sequence[float]) -> float:
        """Fold one measured per-stage step-time vector into the EMA
        and return the current drift."""
        m = [float(x) for x in measured]
        if len(m) != len(self.planned):
            raise ValueError(f"measured vector has {len(m)} stages, "
                             f"plan has {len(self.planned)}")
        if any(x <= 0 for x in m):
            raise ValueError(f"measured stage times must be positive, "
                             f"got {m}")
        if self.ema is None:
            self.ema = m
        else:
            a = self.alpha
            self.ema = [a * x + (1.0 - a) * e
                        for x, e in zip(m, self.ema)]
        self.n_samples += 1
        return self.drift()

    def _shares(self) -> tuple[list[float], list[float]]:
        pt = sum(self.planned)
        mt = sum(self.ema)
        return ([p / pt for p in self.planned],
                [m / mt for m in self.ema])

    def drift(self) -> float:
        if self.ema is None:
            return 0.0
        p, m = self._shares()
        return max(abs(mi - pi) / pi for pi, mi in zip(p, m))

    def should_replan(self) -> bool:
        return self.n_samples >= self.min_samples \
            and self.drift() > self.threshold

    def slowdown(self) -> tuple[float, ...]:
        """Per-stage measured/planned share ratio (> 1 = that stage is
        slower than the plan assumed).  Identity vector until the first
        update."""
        if self.ema is None:
            return tuple(1.0 for _ in self.planned)
        p, m = self._shares()
        return tuple(mi / pi for pi, mi in zip(p, m))


def planned_stage_costs(cfg: ArchConfig, plan, seq: int = 4096) -> list[float]:
    """Analytic per-stage fwd+bwd cost vector under ``plan`` (trunk
    layers only, flops units) — the PLANNED side of the drift monitor.
    Device-independent: the monitor compares normalised shares, so any
    homogeneous per-flop rate cancels.  Stages that own no real layer
    (extreme padding) are floored to a tiny positive cost."""
    prof = profile_arch(cfg, seq=seq)
    S, V, Lc = plan.n_stages, plan.virtual, plan.layers_per_stage
    out = []
    for n in range(S):
        c = 0.0
        for v in range(V):
            chunk = v * S + n
            for l in range(chunk * Lc, (chunk + 1) * Lc):
                if l < cfg.n_layers:
                    lp = prof.layers[l]
                    c += lp.flops_fwd + lp.flops_bwd
        out.append(c)
    floor = 1e-6 * max(out) if max(out) > 0 else 1.0
    return [max(c, floor) for c in out]
