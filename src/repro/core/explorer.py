"""BaPipe automatic exploration (paper Fig. 3).

Profile -> balanced partition -> schedule selection, with data parallelism
evaluated as a first-class alternative (the paper's ResNet-50 result: the
explorer must be able to answer "don't pipeline, use DP").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.hardware import ClusterSpec, FleetSpec
from repro.core.partition import (PartitionPlan, comm_bound, coarse_partition,
                                  dp_partition, interleaved_partition,
                                  intra_layer_refine, memory_fine_tune,
                                  plan_costs_3d, stage_memory,
                                  stage_memory_3d)
from repro.core.profiler import NetworkProfile, bwd_time, fwd_time
from repro.core.schedules import (GradSyncEval, HETERO_SCHEDULES, SCHEDULES,
                                  ScheduleEval, eval_1f1b_interleaved,
                                  eval_1f1b_interleaved_hetero,
                                  eval_1f1b_interleaved_memlean,
                                  eval_1f1b_interleaved_memlean_hetero,
                                  eval_grad_sync, eval_grad_sync_costs,
                                  eval_zb_auto, eval_zb_auto_hetero,
                                  schedules_for)

FEAT_MULT = {"1F1B-AS": 1, "FBP-AS": 2, "1F1B-SNO": 1, "1F1B-SO": 2,
             "1F1B-I": 1, "1F1B-I-ML": 1, "DAPPLE": 1, "ZB-H1": 1,
             "ZB-H2": 1, "ZB-AUTO": 1}

INTERLEAVED_SCHEDULES = ("1F1B-I", "1F1B-I-ML")


@dataclasses.dataclass
class ExplorationResult:
    mode: str                       # "pipeline" | "data_parallel"
    schedule: Optional[str]
    M: int                          # micro-batches per mini-batch
    microbatch: int                 # units per micro-batch
    plan: Optional[PartitionPlan]
    minibatch_time: float
    per_stage_memory: list[float]
    feasible: bool
    sched_eval: Optional[ScheduleEval] = None
    dp_time: float = float("inf")
    dp_feasible: bool = False
    V: int = 1                      # virtual-stage interleave depth (1F1B-I)
    dp_degree: int = 1              # data replicas of the candidate mesh
    # overlap-aware gradient-sync cost of the winning candidate (dp > 1
    # only): minibatch_time already includes ``grad_sync_eval.exposed``
    grad_sync_eval: Optional[GradSyncEval] = None

    @property
    def speedup_over_dp(self) -> float:
        return self.dp_time / self.minibatch_time if self.minibatch_time else 0.0


# ---------------------------------------------------------------------------
# Data-parallel baseline model (synchronous ring all-reduce).
# ---------------------------------------------------------------------------

def dp_time_and_memory(prof: NetworkProfile, cluster: ClusterSpec,
                       minibatch: int) -> tuple[float, float, bool]:
    N = cluster.n
    per_dev = max(1, minibatch // N)
    slowest = 0.0
    for dev in cluster.devices:
        t = sum(fwd_time(l, dev, per_dev) + bwd_time(l, dev, per_dev)
                for l in prof.layers)
        if prof.embed is not None:
            t += fwd_time(prof.embed, dev, per_dev) + bwd_time(prof.embed, dev, per_dev)
        if prof.head is not None:
            t += fwd_time(prof.head, dev, per_dev) + bwd_time(prof.head, dev, per_dev)
        slowest = max(slowest, t)
    wbytes = prof.total_bytes_weights()
    if prof.embed is not None:
        wbytes += prof.embed.bytes_weights
    if prof.head is not None:
        wbytes += prof.head.bytes_weights
    # the gradient buckets ride the data-axis links, not the pipeline
    # boundary (per-axis bandwidth table in hardware.py)
    link = cluster.axis_bandwidth("data")
    allreduce = 2.0 * (N - 1) / N * wbytes / link if N > 1 else 0.0
    t_total = slowest + allreduce
    act = sum(l.bytes_act_out for l in prof.layers) * per_dev
    mem = 2.0 * wbytes + act
    feasible = all(mem <= d.memory_capacity for d in cluster.devices)
    return t_total, mem, feasible


# ---------------------------------------------------------------------------
# The exploration loop.
# ---------------------------------------------------------------------------

def _candidate_Ms(minibatch: int, n_stages: int) -> list[int]:
    ms = []
    m = 1
    while m <= minibatch:
        ms.append(m)
        m *= 2
    # always consider M = 2N and 4N (enough to hide the bubble)
    for extra in (2 * n_stages, 4 * n_stages):
        if extra <= minibatch and extra not in ms:
            ms.append(extra)
    return sorted(ms)


def explore(prof: NetworkProfile, cluster: ClusterSpec, minibatch: int,
            candidate_Ms: Optional[Sequence[int]] = None,
            consider_dp: bool = True,
            candidate_Vs: Sequence[int] = (2, 4),
            mem_limit: Optional[int] = None,
            hetero: bool = True,
            dp_degree: int = 1) -> ExplorationResult:
    """Run the full BaPipe exploration and return the chosen plan.

    With ``hetero`` (the default) the V=1 async candidates are ranked by
    the *scheduled heterogeneous makespan*: the partition's per-device
    cost vector (``PartitionPlan.cost_vector()`` — per-device F and the
    profiled B/W backward split) feeds the ``eval_*_hetero`` forms,
    which replay the schedule's op table under per-device durations
    instead of collapsing ``plan.stage_costs`` to bottleneck scalars;
    the ``ZB-AUTO`` entry's table is *shaped* by the vector (and
    structurally never worse than the table the scalar collapse would
    build).  Uniform vectors reduce bit-exactly to the scalar forms.
    The vector also carries per-hop SR_n from each boundary's actual
    link bandwidth; the ranking itself keeps the async free-comm
    premise (as every Table-1 form does), while the SR-aware path —
    ``build_zb_auto(costs=StageCosts)`` + ``simulate_costs`` — consumes
    the hops directly.  ``hetero=False`` keeps the legacy scalar
    collapse — the uniform-cost baseline the differential tests and the
    skewed-cluster benchmark compare against.

    ``candidate_Vs`` are the interleave depths tried for the interleaved
    schedules (``1F1B-I`` and its memory-lean order ``1F1B-I-ML``; async
    clusters only); V=1 of 1F1B-I is identical to 1F1B-AS, which is always
    searched, so only V > 1 is explored here.  ``1F1B-I-ML`` matches
    1F1B-I's makespan with a smaller resident-features term, so it wins
    exactly when memory gates the streaming order (ties prefer the
    schedule found first).

    ``mem_limit`` caps the ``ZB-AUTO`` entry's peak-live row (None =
    unbounded).  The zero-bubble family degrades gracefully along the
    memory axis: unbounded ZB-AUTO is fully bubble-free at M resident
    activations, ZB-H2 keeps only the fill ramp at ~2x 1F1B's window,
    ZB-H1 halves the drain term at exactly 1F1B's window — so the
    explorer lands on the fastest entry whose features row fits the
    devices.

    ``dp_degree`` is the number of data replicas of the candidate mesh
    (``minibatch`` stays per-replica).  With ``dp_degree > 1`` every
    candidate additionally pays its gradient synchronisation over the
    ``data`` axis — but only the *exposed* part: the per-stage buckets
    are scheduled into the drain bubble (:func:`eval_grad_sync` /
    :func:`eval_grad_sync_costs`, the AR op model the simulator
    replays), so a bubbled schedule hides most of its sync and the DP
    degree enters the ranking honestly instead of as a flat
    ``sum(ar)`` tax.
    """
    N = cluster.n
    if dp_degree < 1:
        raise ValueError(f"dp_degree must be >= 1, got {dp_degree}")
    dp_t, dp_mem, dp_ok = dp_time_and_memory(prof, cluster, minibatch)
    async_ok = all(d.async_capable for d in cluster.devices)
    scheds = schedules_for(async_ok)
    best: Optional[ExplorationResult] = None
    Ms = list(candidate_Ms) if candidate_Ms else _candidate_Ms(minibatch, N)
    for sched in scheds:
        feat_mult = FEAT_MULT[sched]
        # async schedules fully overlap comm; sync-overlap hides comm too,
        # sync-no-overlap pays it on the critical path.
        overlap = sched != "1F1B-SNO"
        if sched in INTERLEAVED_SCHEDULES:
            # a device must own V chunks of >= 1 layer each
            Vs = tuple(v for v in candidate_Vs
                       if v > 1 and N * v <= prof.n_layers)
        else:
            Vs = (1,)
        for V in Vs:
            for M in Ms:
                if M < 1 or minibatch // M < 1:
                    continue
                if V > 1 and M < N:
                    continue       # interleave streaming constraint (M >= N)
                if sched == "1F1B-I-ML" and M % N != 0:
                    continue       # Megatron group constraint (M % N == 0)
                mb = minibatch // M
                plan = interleaved_partition(prof, cluster, mb, V,
                                             overlap=overlap)
                if comm_bound(plan):
                    plan = coarse_partition(prof, cluster, mb, overlap, V=V)
                plan, mem_ok = memory_fine_tune(prof, cluster, plan, mb,
                                                feat_mult, M, schedule=sched,
                                                mem_limit=mem_limit)
                if not comm_bound(plan) and V == 1:
                    # intra-layer (fractional) balancing LAST — memory
                    # fine-tuning re-finalises integer bounds and would
                    # discard the fractional shifts
                    plan = intra_layer_refine(prof, cluster, plan, mb)
                F, B = plan.bottleneck_FB()
                # sync/interleaved scalar forms keep the conservative
                # worst-hop SR; the hetero path carries the per-hop
                # SR_n vector inside plan.cost_vector() instead
                SR = max((max(c.comm_in, c.comm_out)
                          for c in plan.stage_costs), default=0.0)
                a = plan.max_boundary_act()
                w = max(c.weight_bytes for c in plan.device_costs())
                costs = plan.cost_vector() if hetero else None
                if V > 1 and sched == "1F1B-I-ML":
                    # hetero V > 1 replays the chunked table at per-device
                    # costs (used to fall through to the scalar closed form
                    # even on skewed clusters — the routing gap this fixes)
                    ev = (eval_1f1b_interleaved_memlean_hetero(
                              M, N, costs, a, w, V=V) if hetero
                          else eval_1f1b_interleaved_memlean(
                              M, N, F, B, SR, a, w, V=V))
                elif V > 1:
                    ev = (eval_1f1b_interleaved_hetero(M, N, costs, a, w,
                                                       V=V) if hetero
                          else eval_1f1b_interleaved(M, N, F, B, SR, a, w,
                                                     V=V))
                elif hetero and sched in HETERO_SCHEDULES:
                    # the sync schedules route here too now: replayed under
                    # blocking (SNO) / latency (SO) comm with per-hop SR
                    if sched == "ZB-AUTO":
                        ev = eval_zb_auto_hetero(M, N, costs, a, w,
                                                 mem_limit=mem_limit)
                    else:
                        ev = HETERO_SCHEDULES[sched](M, N, costs, a, w)
                elif sched == "ZB-AUTO":
                    ev = eval_zb_auto(M, N, F, B, SR, a, w,
                                      mem_limit=mem_limit)
                else:
                    ev = SCHEDULES[sched](M, N, F, B, SR, a, w)
                mem = stage_memory(plan, feat_mult, M, schedule=sched,
                                   mem_limit=mem_limit)
                t = ev.minibatch_time
                if not mem_ok:
                    # paper §4.3: weights kept on-chip "as much as
                    # possible"; the remainder streams from the spill tier
                    # every micro-batch
                    spill_bw = min(d.spill_bandwidth for d in cluster.devices)
                    if spill_bw <= 0:
                        continue
                    spill = max(m - d.memory_capacity
                                for m, d in zip(mem, cluster.devices))
                    t += M * spill / spill_bw
                gs = None
                if dp_degree > 1:
                    # per-stage bucket time: ring RS+AG of the stage's
                    # gradient bytes over the data-axis links
                    data_bw = cluster.axis_bandwidth("data")
                    ar_vec = [2.0 * (dp_degree - 1) / dp_degree
                              * c.weight_bytes / data_bw
                              for c in plan.device_costs()]
                    ml = mem_limit if sched == "ZB-AUTO" else None
                    if hetero and V == 1 and costs is not None:
                        gs = eval_grad_sync_costs(sched, M, N, costs,
                                                  ar_vec, mem_limit=ml)
                    else:
                        gs = eval_grad_sync(sched, M, N, F, B, ar_vec,
                                            V=V, mem_limit=ml)
                    t += gs.exposed
                cand = ExplorationResult(
                    mode="pipeline", schedule=sched, M=M, microbatch=mb,
                    plan=plan, minibatch_time=t,
                    per_stage_memory=mem, feasible=True, sched_eval=ev,
                    dp_time=dp_t, dp_feasible=dp_ok, V=V,
                    dp_degree=dp_degree, grad_sync_eval=gs)
                if best is None or cand.minibatch_time < best.minibatch_time \
                        * 0.999:
                    best = cand
                elif (cand.minibatch_time < best.minibatch_time * 1.001
                      and best.sched_eval is not None
                      and ev.bandwidth_demand
                      < best.sched_eval.bandwidth_demand):
                    # tie-break on demanded link bandwidth (paper §3.2.1:
                    # FPGAs pick FBP-AS when times tie — gentler 2a/(F+B)
                    # demand)
                    best = cand
    if best is None:
        best = ExplorationResult(
            mode="pipeline", schedule=scheds[0], M=1, microbatch=minibatch,
            plan=None, minibatch_time=float("inf"), per_stage_memory=[],
            feasible=False, dp_time=dp_t, dp_feasible=dp_ok)
    if consider_dp and dp_ok and dp_t < best.minibatch_time:
        return ExplorationResult(
            mode="data_parallel", schedule=None, M=1, microbatch=minibatch,
            plan=None, minibatch_time=dp_t, per_stage_memory=[dp_mem] * N,
            feasible=True, dp_time=dp_t, dp_feasible=True)
    return best


# ---------------------------------------------------------------------------
# 3D exploration: per-stage (dp, tp) degrees over a device pool.
# ---------------------------------------------------------------------------

# canonical builder names the cost-shaped replay accepts — the 3D
# candidates are ranked by simulator replay, so only replayable
# schedules participate
PLAN3D_SCHEDULES = ("1f1b", "zb-h1")


@dataclasses.dataclass(frozen=True)
class Plan3D:
    """One point of the 3D search space: a layer partition plus a
    per-stage ``(dp, tp)`` chip grid, ranked by the cost-shaped
    simulator replay of its schedule (makespan + exposed grad sync)."""
    bounds: tuple[tuple[int, int], ...]   # per-stage [start, end) layers
    shards: tuple[tuple[int, int], ...]   # per-stage (dp, tp)
    schedule: str
    M: int                                # micro-batches per mini-batch
    microbatch: int                       # units per micro-batch
    costs: object                         # TP-aware StageCosts (width-annotated)
    predicted_time: float                 # replay makespan + exposed sync
    sim_makespan: float                   # replay makespan, sync-free
    sync_exposed: float
    per_chip_memory: tuple[float, ...] = ()

    @property
    def n_stages(self) -> int:
        return len(self.bounds)

    @property
    def devices_used(self) -> int:
        return sum(d * t for d, t in self.shards)

    @property
    def uniform(self) -> bool:
        """All stages share one (dp, tp) — the plan maps onto a regular
        ``(data, stage, tensor)`` mesh and is directly executable; a
        non-uniform plan is ranked analytically (simulator replay)
        until the runtime grows ragged-mesh support."""
        return len(set(self.shards)) == 1

    @property
    def pipeline_only(self) -> bool:
        return all(s == (1, 1) for s in self.shards)


@dataclasses.dataclass
class Exploration3DResult:
    best: Plan3D
    incumbent: Plan3D                     # best pipeline-only plan
    candidates: list                      # all feasible plans, ranked

    @property
    def speedup_over_1d(self) -> float:
        return (self.incumbent.predicted_time / self.best.predicted_time
                if self.best.predicted_time else 0.0)


def _rank_3d(prof: NetworkProfile, fleet: FleetSpec, bounds, shards,
             schedule: str, M: int, mb: int, mem_limit,
             enforce_memory: bool) -> Optional[Plan3D]:
    """Cost one (bounds, shards, schedule, M) point and replay it."""
    from repro.core.schedules import eval_grad_sync_costs
    base = fleet.base
    S = len(bounds)
    costs = plan_costs_3d(prof, base, bounds, mb, shards)
    mem = stage_memory_3d(prof, bounds, shards, mb)
    if enforce_memory and any(m > base.memory_capacity for m in mem):
        return None
    data_bw = base.axis_bandwidth("data")
    ar_vec = []
    for (s, e), (dp, tp) in zip(bounds, shards):
        wbytes = sum(prof.layers[k].bytes_weights for k in range(s, e))
        ar_vec.append(0.0 if dp <= 1 else
                      2.0 * (dp - 1) / dp * (wbytes / tp) / data_bw)
    gs = eval_grad_sync_costs(schedule, M, S, costs, ar_vec,
                              mem_limit=mem_limit)
    return Plan3D(
        bounds=tuple(tuple(b) for b in bounds),
        shards=tuple(tuple(s) for s in shards),
        schedule=schedule, M=M, microbatch=mb, costs=costs,
        predicted_time=gs.overlapped, sim_makespan=gs.compute_makespan,
        sync_exposed=gs.exposed, per_chip_memory=tuple(mem))


def _uniform_factorisations(chips: int) -> list[tuple[int, int]]:
    """All (dp, tp) integer factorisations of a stage's chip count."""
    return [(d, chips // d) for d in range(1, chips + 1)
            if chips % d == 0]


def explore3d(prof: NetworkProfile, fleet: FleetSpec, minibatch: int,
              candidate_Ms: Optional[Sequence[int]] = None,
              schedules: Sequence[str] = PLAN3D_SCHEDULES,
              candidate_stage_counts: Optional[Sequence[int]] = None,
              mem_limit: Optional[int] = None,
              enforce_memory: bool = False) -> Exploration3DResult:
    """BaPipe's balanced-partition exploration generalized to 3D: each
    pipeline stage gets a ``(dp, tp)`` chip grid carved from the
    ``fleet`` pool, under the pool's device budget.

    The space has three candidate families, all ranked by the SAME
    cost-shaped simulator replay (makespan of the schedule's op table
    under the TP-aware per-stage durations, plus the exposed part of
    the dp gradient sync — :func:`eval_grad_sync_costs`):

    * **pipeline-only** (every stage ``(1, 1)``): the incumbent 1D
      space — one plan per stage count.  Always searched, so the 3D
      result is structurally never worse than the 1D explorer's
      ranking of the same schedules.
    * **uniform (dp, tp)**: for every stage count S dividing the pool
      and every factorisation ``dp * tp = budget // S``.  These map
      onto a regular ``(data, stage, tensor)`` mesh and are directly
      executable by the runtime.
    * **non-uniform tp** (greedy width promotion): starting from width
      1 everywhere, repeatedly double the TP width of the
      bottleneck-time stage while the budget allows, re-balancing the
      layer split against the widened chain each step.  These let a
      fat stage buy width where depth can't split it; they are ranked
      analytically (the runtime executes uniform plans only).

    Layer bounds come from the existing balanced partitioner run
    against the width-fused chain (``fleet.chain``); the exact TP
    costing — collectives, reshard SR, width-sharded memory — is then
    applied by :func:`repro.core.partition.plan_costs_3d`.
    ``enforce_memory`` drops candidates whose per-chip memory exceeds
    the base device's capacity (off by default: the analytic fixtures
    probe time, not capacity)."""
    P = fleet.n_devices
    base = fleet.base
    if not fleet.homogeneous:
        raise ValueError("explore3d plans over homogeneous pools; "
                         "heterogeneous chains go through explore()")
    for s in schedules:
        if s not in PLAN3D_SCHEDULES:
            raise ValueError(f"schedule {s!r} not replayable; "
                             f"pick from {PLAN3D_SCHEDULES}")
    Ss = (list(candidate_stage_counts) if candidate_stage_counts
          else [S for S in range(1, P + 1) if S <= prof.n_layers])
    candidates: list[Plan3D] = []

    def bounds_for(widths) -> tuple:
        chain = fleet.chain(widths)
        if chain.n == 1:
            return ((0, prof.n_layers),)
        return dp_partition(prof, chain, max(1, minibatch),
                            overlap=True).bounds

    def rank_all(bounds, shards, S):
        mbs = candidate_Ms or sorted({min(2 * S, minibatch),
                                      min(4 * S, minibatch),
                                      min(8 * S, minibatch)})
        for sched in schedules:
            for M in mbs:
                if M < 1 or minibatch // M < 1:
                    continue
                mb = minibatch // M
                # every dp replica needs a whole number of units
                if any(mb % dp for dp, _ in shards):
                    continue
                cand = _rank_3d(prof, fleet, bounds, shards, sched, M, mb,
                                mem_limit, enforce_memory)
                if cand is not None:
                    candidates.append(cand)

    for S in Ss:
        # pipeline-only + uniform (dp, tp): need S * dp * tp == P
        if P % S == 0:
            chips = P // S
            for dp, tp in _uniform_factorisations(chips):
                widths = [tp] * S      # dp replicates the chain, tp fuses
                bounds = bounds_for(widths)
                rank_all(bounds, [(dp, tp)] * S, S)
        elif S <= P:
            # budget doesn't divide: pipeline-only on S chips still valid
            bounds = bounds_for([1] * S)
            rank_all(bounds, [(1, 1)] * S, S)
        # greedy non-uniform width promotion (tp only, dp = 1)
        if S < 2 or S >= P:
            continue
        widths = [1] * S
        while True:
            costs = plan_costs_3d(prof, base, bounds_for(widths),
                                  max(1, minibatch), [(1, w) for w in widths])
            totals = [f + b + w for f, b, w
                      in zip(costs.F, costs.B, costs.W)]
            order = sorted(range(S), key=lambda i: -totals[i])
            bumped = False
            for i in order:
                if sum(widths) + widths[i] <= P:
                    widths[i] *= 2
                    bumped = True
                    break
            if not bumped:
                break
            shards = [(1, w) for w in widths]
            if len(set(shards)) > 1:          # uniform handled above
                rank_all(bounds_for(widths), shards, S)

    if not candidates:
        raise ValueError(f"no feasible 3D candidate for {P} devices / "
                         f"{prof.n_layers} layers / minibatch {minibatch}")
    candidates.sort(key=lambda c: c.predicted_time)
    pipeline_only = [c for c in candidates if c.pipeline_only]
    if not pipeline_only:
        raise AssertionError("incumbent pipeline-only plan missing from "
                             "the 3D space")  # structurally impossible
    return Exploration3DResult(best=candidates[0],
                               incumbent=pipeline_only[0],
                               candidates=candidates)


# ---------------------------------------------------------------------------
# Baseline frameworks for Table 3 / Table 4 (analytic counterparts).
# ---------------------------------------------------------------------------

def gpipe_time(prof: NetworkProfile, cluster: ClusterSpec, minibatch: int,
               M: int) -> tuple[float, list[float]]:
    """GPipe: all-FP then all-BP; stores ALL M micro-batch activations
    (no recompute, as in the paper's comparison); uses BaPipe's partition."""
    mb = max(1, minibatch // M)
    plan = dp_partition(prof, cluster, mb, overlap=False)
    F, B = plan.balanced_F(), plan.balanced_B()
    SR = max((max(c.comm_in, c.comm_out) for c in plan.stage_costs), default=0.0)
    N = cluster.n
    t = (M + N - 1) * (F + B) + (N + M - 2) * 2 * SR
    mem = [2.0 * c.weight_bytes + M * c.act_out_bytes for c in plan.stage_costs]
    return t, mem


def pipedream_time(prof: NetworkProfile, cluster: ClusterSpec, minibatch: int
                   ) -> tuple[float, list[float]]:
    """PipeDream: inter-batch 1F1B, no bubble in steady state, but weight
    stashing keeps up to N weight versions per stage."""
    mb = minibatch                 # PipeDream pipelines whole minibatches
    plan = dp_partition(prof, cluster, mb, overlap=False)
    F, B = plan.balanced_F(), plan.balanced_B()
    SR = max((max(c.comm_in, c.comm_out) for c in plan.stage_costs), default=0.0)
    N = cluster.n
    t = (F + B) + 2 * SR           # steady-state per mini-batch
    mem = [(N - i) * 2.0 * c.weight_bytes + (N - i) * c.act_out_bytes
           for i, c in enumerate(plan.stage_costs)]
    return t, mem
