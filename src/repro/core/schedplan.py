"""Schedule-plan IR: one compiled per-device op table that drives the
closed forms (:mod:`repro.core.schedules`), the discrete-event simulator
(:mod:`repro.core.simulator`) and the SPMD tick-scan runtime
(:mod:`repro.pipeline.runtime`).

Before this module each schedule's op order was encoded three times —
closed-form arithmetic, the simulator's private ``_order_*`` generators,
and the runtime's tick-index arithmetic — and every new ordering (the
ROADMAP's memory-lean 1F1B-I, interleaved prefill serving) had to be
implemented thrice.  Here the order is *data*: a :class:`SchedPlan` holds,
per physical device, the exact sequence of ``F``/``B`` ops tagged with
micro-batch ``m`` and virtual chunk ``v``; consumers replay it.

Eight builders (canonical lowercase names):

* ``gpipe``            — all forwards, then all backwards.
* ``1f1b``             — one-forward-one-backward; warm-up ``N - n`` per
  device (``double_warmup=True`` gives the ``2(N-n)-1`` warm-up shared by
  FBP-AS and 1F1B-SO).
* ``1f1b-interleaved`` — V virtual chunks per device, *streaming* chunk
  passes: all M micro-batches finish pass v before pass v+1 enters (the
  circular-``ppermute`` order PR 1's runtime executes).  Warm-up
  ``(V-1)M + N - n`` so peak resident features carry the ``(V-1)M`` term.
* ``1f1b-interleaved-memlean`` — Megatron/PipeDream-2BW ordering
  (PAPERS.md "Memory-Efficient Pipeline-Parallel DNN Training"):
  micro-batches advance in groups of N, cycling chunks inside each group,
  with warm-up ``2(N - n - 1) + (V-1)N``.  Same makespan as the streaming
  order, but the resident-features term drops from ``(V-1)M`` to
  ``(V-1)N`` — the schedule that makes memory-gated interleaved plans
  feasible.  Requires ``M % N == 0`` (Megatron's constraint) so every
  ring return is consumed exactly N ticks after it was produced.
* ``dapple``           — DAPPLE's early-backward schedule (arXiv
  2007.01045): warm-up ``N - n`` forwards, then strict one-backward-
  one-forward alternation.  The op table coincides with synchronous 1F1B
  (the schedule DAPPLE popularised); it is kept as its own builder so the
  runtime's *executed backward order* — not just an analytic row — names
  the paper it reproduces.
* ``zb-h1``            — zero-bubble H1 (arXiv 2211.05953): the backward
  is split into an input-gradient op ``B`` and a weight-gradient op ``W``
  (``W`` has no stage-boundary edges, so it fills what would otherwise be
  drain bubbles).  Per device: warm-up ``N - n`` forwards, then
  ``B, W, F`` steady cycles, then ``B, W`` drain pairs.  Peak resident
  features stay at 1F1B's ``N - n`` while the bubble shrinks from
  ``(N-1)(F + B)`` to ``(N-1)(F + B/2)`` (B split evenly into B/W).
* ``zb-h2``            — zero-bubble H2: warm-up deepens to
  ``2(N-n) - 1`` and the downstream devices bank weight-gradients past
  the drain, removing the whole flush bubble — makespan
  ``M(F+B) + (N-1)F`` at ~2x 1F1B's memory.  Derived as ``zb-auto``
  under :func:`zb_h2_mem_caps` at unit costs.
* ``zb-auto``          — the *automatic* zero-bubble scheduler: a
  cost-driven greedy list scheduler over F/B/W placement under a
  per-device peak-live ``mem_limit`` cap (None = unbounded -> fully
  bubble-free steady state at M resident activations), with a portfolio
  fallback that makes it never worse than ``zb-h1`` whenever the cap
  admits the 1F1B window.  The 1F1B cap reproduces ``zb-h1``'s table
  exactly; :func:`zb_h2_mem_caps` reproduces ``zb-h2``'s.

Legacy schedule-table names ("1F1B-AS", "FBP-AS", "1F1B-SNO", "1F1B-SO",
"1F1B-I", "1F1B-I-ML", "DAPPLE", "ZB-H1", "ZB-H2", "ZB-AUTO") alias onto
these builders via :func:`build_schedule` / :func:`canonical_name`.

Two derived views:

* :meth:`SchedPlan.peak_live` — symbolic replay of each device's op list
  (F = +1 live chunk activation, B = -1) giving the per-device peak
  resident-features count.  :func:`live_activation_counts` is the O(1)
  algebraic form of the same quantity, differentially tested against the
  replay.
* :func:`lower_to_ring` — compiles the plan's forward order into the
  per-element lookup arrays the forward-only tick-scan (serving) consumes
  (micro-batch, chunk, fresh-injection and output-collection flags), and
  validates ring feasibility: element e's previous chunk pass must have
  re-entered stage 0 by the tick e is issued.
* :func:`lower_to_ticks` — the full mixed lowering the *training* runtime
  executes: assigns every F/B/W op a synchronous tick (one op per device
  per tick, one-tick neighbour hops on the forward/backward ppermute
  rings), and statically allocates the residual stash (stage inputs,
  alive F -> B/W: exactly the schedule's peak-live row), the
  forward/backward inbox slots (arrivals the consuming op is not ready
  for yet) and the ZB cotangent stash (alive B -> W).  Backward ops are
  first-class ticks: the runtime replays this table instead of
  autodiffing the forward scan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union


@dataclasses.dataclass(frozen=True)
class StageCosts:
    """First-class per-device cost vector — the heterogeneous
    generalisation of the scalar ``(F, B, SR)`` interface (BaPipe's §V
    FPGA clusters are heterogeneous; collapsing the partitioner's
    per-stage times into bottleneck scalars before the schedule sees
    them throws the balance information away).

    ``F[n]`` / ``B[n]`` / ``W[n]`` are device n's forward,
    input-gradient and weight-gradient times per micro-batch (the full
    backward is ``B[n] + W[n]``; two-op schedules simply run the full
    backward, zero-bubble schedules split it).  ``SR[k]`` is the
    send/receive time of the boundary between devices k and k+1 —
    per *hop*, from that boundary's actual link bandwidth, not a
    ``max`` over the chain.

    Consumers: :func:`build_zb_auto` shapes its table by the vector,
    :func:`repro.core.simulator.simulate` replays any plan under
    per-device durations, and the ``eval_*_hetero`` closed forms in
    :mod:`repro.core.schedules` reduce to the uniform forms exactly
    when :attr:`uniform` holds.

    ``width[n]`` annotates how many chips device n's stage actually
    occupies (its ``dp * tp`` shard of the 3D plan; empty = all 1).
    The times already price the sharding — width changes no replay
    duration — but the annotation travels with the vector so the
    simulator and the hetero evals can report device-seconds and
    budget-normalised makespans for non-uniform candidates."""
    F: tuple[float, ...]
    B: tuple[float, ...]
    W: tuple[float, ...]
    SR: tuple[float, ...] = ()
    width: tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "F", tuple(float(x) for x in self.F))
        object.__setattr__(self, "B", tuple(float(x) for x in self.B))
        object.__setattr__(self, "W", tuple(float(x) for x in self.W))
        object.__setattr__(self, "SR", tuple(float(x) for x in self.SR))
        object.__setattr__(self, "width",
                           tuple(int(w) for w in self.width))
        n = len(self.F)
        if not (len(self.B) == len(self.W) == n):
            raise ValueError(f"StageCosts vectors disagree on N: "
                             f"F={len(self.F)} B={len(self.B)} "
                             f"W={len(self.W)}")
        if self.SR and len(self.SR) != n - 1:
            raise ValueError(f"StageCosts.SR needs one entry per hop "
                             f"({n - 1}), got {len(self.SR)}")
        if any(x <= 0 for x in self.F + self.B + self.W):
            raise ValueError(f"StageCosts times must be positive: {self}")
        if any(x < 0 for x in self.SR):
            raise ValueError(f"StageCosts.SR must be >= 0: {self.SR}")
        if self.width:
            if len(self.width) != n:
                raise ValueError(f"StageCosts.width needs one entry per "
                                 f"device ({n}), got {len(self.width)}")
            if any(w < 1 for w in self.width):
                raise ValueError(f"StageCosts.width must be >= 1: "
                                 f"{self.width}")

    @property
    def n(self) -> int:
        return len(self.F)

    @property
    def widths(self) -> tuple[int, ...]:
        """Per-device chip widths, materialised (ones when unannotated)."""
        return self.width if self.width else (1,) * self.n

    @property
    def uniform_width(self) -> bool:
        """All stages occupy the same chip width — the regime the SPMD
        runtime can execute directly on a rectangular mesh; non-uniform
        widths stay analytic (simulator-ranked)."""
        return len(set(self.widths)) == 1

    def devices_used(self) -> int:
        """Total chips the annotated plan occupies."""
        return sum(self.widths)

    @property
    def B_full(self) -> tuple[float, ...]:
        """Per-device full backward time (input-grad + weight-grad)."""
        return tuple(b + w for b, w in zip(self.B, self.W))

    @property
    def w_frac(self) -> tuple[float, ...]:
        """Per-device weight-gradient fraction of the full backward."""
        return tuple(w / (b + w) for b, w in zip(self.B, self.W))

    @property
    def sr_hops(self) -> tuple[float, ...]:
        """Per-hop SR, materialised (zeros when unspecified)."""
        return self.SR if self.SR else (0.0,) * max(0, self.n - 1)

    @property
    def uniform(self) -> bool:
        """All devices share one (F, B, W) and all hops one SR — the
        regime where every hetero form must reduce to the uniform one."""
        return (len(set(self.F)) == 1 and len(set(self.B)) == 1
                and len(set(self.W)) == 1 and len(set(self.sr_hops)) <= 1)

    @property
    def even_split(self) -> bool:
        """Every device's backward splits evenly (B == W) — the design
        point the uniform zero-bubble closed forms assume."""
        return all(b == w for b, w in zip(self.B, self.W))

    def bottleneck(self) -> tuple[float, float, float]:
        """The legacy scalar collapse ``(max F, max B_full, max SR)`` —
        what the explorer fed the schedule formulas before costs were
        first-class.  Kept for the uniform-scalar portfolio/baselines."""
        return (max(self.F), max(self.B_full),
                max(self.sr_hops, default=0.0))

    def max_scalar(self) -> "StageCosts":
        """Uniform collapse: every device pays the bottleneck device's
        times (and every hop the worst hop) — the cost vector the old
        scalar interface implied.  The width annotation is preserved:
        collapsing times says nothing about chip occupancy."""
        return StageCosts(F=(max(self.F),) * self.n,
                          B=(max(self.B),) * self.n,
                          W=(max(self.W),) * self.n,
                          SR=(max(self.sr_hops, default=0.0),)
                          * max(0, self.n - 1),
                          width=self.width)

    @classmethod
    def uniform_costs(cls, N: int, F: float, B_full: float,
                      SR: float = 0.0, w_frac: float = 0.5
                      ) -> "StageCosts":
        """Lift the scalar interface into a (trivially uniform) vector."""
        return cls(F=(float(F),) * N,
                   B=(B_full * (1.0 - w_frac),) * N,
                   W=(B_full * w_frac,) * N,
                   SR=(float(SR),) * max(0, N - 1))


CostVec = Union[float, Sequence[float]]


def _cost_vec(x: CostVec, N: int, what: str) -> list[float]:
    """Normalise a scalar-or-sequence cost knob to a length-N list."""
    if isinstance(x, (int, float)):
        return [float(x)] * N
    xs = [float(v) for v in x]
    if len(xs) != N:
        raise ValueError(f"{what} needs one entry per device ({N}), "
                         f"got {len(xs)}")
    return xs


@dataclasses.dataclass(frozen=True)
class Op:
    """One unit of pipeline work: the F, B (input-gradient) or W
    (weight-gradient, zero-bubble split) of micro-batch ``m`` on chunk
    ``v`` of a device.  ``vstage`` is the global virtual-stage index; the
    send/recv edges are the stage-boundary transfers the op participates
    in (``None`` at the chain ends; ``W`` never transfers — it only
    consumes the residual and cotangent its ``B`` left behind).

    ``AR`` is the data-parallel gradient synchronisation of one
    parameter bucket (chunk ``v``'s stage-layer group): a bucketed
    reduce-scatter/all-gather over the ``data`` mesh axis, ready as soon
    as the device's last B/W for the bucket has retired (``m`` is always
    0 — the bucket sums over micro-batches).  AR never touches the
    stage-boundary rings; it rides the shared data-axis fabric instead,
    so ``send_to``/``recv_from`` are None."""
    kind: str                       # "F" | "B" | "W" | "AR"
    m: int                          # micro-batch index
    v: int                          # chunk index on this device (0..V-1)
    device: int                     # physical device n (0..N-1)
    n_stages: int                   # N (to derive virtual-stage indices)
    n_chunks: int                   # V

    @property
    def vstage(self) -> int:
        return self.v * self.n_stages + self.device

    @property
    def send_to(self) -> Optional[int]:
        """Virtual stage this op's output is sent to (forward: activation
        to vstage+1; backward: error to vstage-1)."""
        last = self.n_stages * self.n_chunks - 1
        if self.kind in ("W", "AR"):
            return None
        if self.kind == "F":
            return self.vstage + 1 if self.vstage < last else None
        return self.vstage - 1 if self.vstage > 0 else None

    @property
    def recv_from(self) -> Optional[int]:
        """Virtual stage this op's input arrives from."""
        last = self.n_stages * self.n_chunks - 1
        if self.kind in ("W", "AR"):
            return None
        if self.kind == "F":
            return self.vstage - 1 if self.vstage > 0 else None
        return self.vstage + 1 if self.vstage < last else None


@dataclasses.dataclass(frozen=True)
class SchedPlan:
    """Compiled per-device op table for one mini-batch of M micro-batches
    through N devices with V virtual chunks per device."""
    name: str
    M: int
    N: int
    V: int
    device_ops: tuple[tuple[Op, ...], ...]   # [N] tuples, issue order

    @property
    def has_w(self) -> bool:
        """True for zero-bubble plans whose backward is split into
        input-gradient (B) and weight-gradient (W) ops."""
        return any(op.kind == "W" for op in self.device_ops[0])

    @property
    def has_grad_sync(self) -> bool:
        """True when the plan schedules the data-parallel gradient sync
        as explicit AR ops (see :func:`add_grad_sync`)."""
        return any(op.kind == "AR"
                   for ops in self.device_ops for op in ops)

    @property
    def grad_sync_groups(self) -> int:
        """Number of per-layer-group AR buckets per (device, chunk)
        (see :func:`add_grad_sync`); 1 for single-bucket plans, 0 when
        the plan has no grad sync."""
        ars = [op.m for ops in self.device_ops for op in ops
               if op.kind == "AR"]
        return (max(ars) + 1) if ars else 0

    def validate(self) -> "SchedPlan":
        """Every (m, chunk) F and B — and W, for zero-bubble plans —
        appears exactly once per device, and the per-(m, v) order is
        F before B before W.  AR ops (grad-sync plans) are G per
        (device, chunk) — ``m`` carries the layer-group index, groups
        ascending within a chunk — each after the bucket's last B/W."""
        has_w = self.has_w
        per_mv = (3 if has_w else 2)
        release = "W" if has_w else "B"
        groups = self.grad_sync_groups
        for n, ops in enumerate(self.device_ops):
            seen: dict[tuple[str, int, int], int] = {}
            for i, op in enumerate(ops):
                key = (op.kind, op.m, op.v)
                if key in seen:
                    raise ValueError(f"{self.name}: duplicate {key} on "
                                     f"device {n}")
                seen[key] = i
            n_ar = sum(1 for op in ops if op.kind == "AR")
            if n_ar not in (0, self.V * groups):
                raise ValueError(
                    f"{self.name}: device {n} has {n_ar} AR ops, expected "
                    f"0 or {groups} per chunk ({self.V * groups})")
            if n_ar:
                by_chunk: dict[int, list[int]] = {}
                for op in ops:
                    if op.kind == "AR":
                        by_chunk.setdefault(op.v, []).append(op.m)
                for v, ms in by_chunk.items():
                    if ms != list(range(groups)):
                        raise ValueError(
                            f"{self.name}: AR(v={v}) on device {n} has "
                            f"group indices {ms}, expected "
                            f"{list(range(groups))} ascending")
                last_release = {
                    op.v: i for i, op in enumerate(ops)
                    if op.kind == release}
                for i, op in enumerate(ops):
                    if op.kind == "AR" and i < last_release.get(op.v, -1):
                        raise ValueError(
                            f"{self.name}: AR(v={op.v}) on device {n} "
                            f"before the bucket's last {release}")
            if len(ops) - n_ar != per_mv * self.M * self.V:
                raise ValueError(
                    f"{self.name}: device {n} has {len(ops) - n_ar} "
                    f"compute ops, expected {per_mv * self.M * self.V}")
            for (kind, m, v), i in seen.items():
                if kind == "B" and seen[("F", m, v)] > i:
                    raise ValueError(f"{self.name}: B({m},{v}) before its F "
                                     f"on device {n}")
                if kind == "W" and seen[("B", m, v)] > i:
                    raise ValueError(f"{self.name}: W({m},{v}) before its B "
                                     f"on device {n}")
            if has_w:
                for (kind, m, v) in list(seen):
                    if kind == "B" and ("W", m, v) not in seen:
                        raise ValueError(f"{self.name}: B({m},{v}) has no W "
                                         f"on device {n}")
        return self

    def forward_sequence(self, device: int = 0) -> list[tuple[int, int]]:
        """(m, v) of the device's forwards in issue order."""
        return [(op.m, op.v) for op in self.device_ops[device]
                if op.kind == "F"]

    def peak_live(self) -> list[int]:
        """Symbolic replay: per-device peak count of resident chunk
        activations (F issued, residual not yet released) — the
        features-memory row the closed forms tabulate, derived directly
        from the table.  The residual is released by the op that last
        reads it: B for two-op plans, W for zero-bubble plans (the
        weight gradient still needs the stage input)."""
        release = "W" if self.has_w else "B"
        peaks = []
        for ops in self.device_ops:
            live = peak = 0
            for op in ops:
                if op.kind == "F":
                    live += 1
                elif op.kind == release:
                    live -= 1
                peak = max(peak, live)
            peaks.append(peak)
        return peaks


# ---------------------------------------------------------------------------
# Builders.
# ---------------------------------------------------------------------------

def _ops_from_seqs(name: str, M: int, N: int, V: int,
                   fwd_seqs, bwd_seqs, warmups) -> SchedPlan:
    """Assemble the 1F1B skeleton: per device, ``warmup`` forwards, then
    alternate (B, F) until the forwards drain, then the remaining
    backwards."""
    device_ops = []
    for n in range(N):
        fwd, bwd = fwd_seqs[n], bwd_seqs[n]
        total = len(fwd)
        warmup = max(1, min(total, warmups[n]))
        mk = lambda kind, mv: Op(kind, mv[0], mv[1], n, N, V)
        ops = [mk("F", mv) for mv in fwd[:warmup]]
        nf, nb = warmup, 0
        while nb < total:
            ops.append(mk("B", bwd[nb])); nb += 1
            if nf < total:
                ops.append(mk("F", fwd[nf])); nf += 1
        device_ops.append(tuple(ops))
    return SchedPlan(name=name, M=M, N=N, V=V,
                     device_ops=tuple(device_ops)).validate()


def build_gpipe(M: int, N: int) -> SchedPlan:
    """All forwards, then all backwards (no interleave): peak resident
    features = M on every device."""
    fwd = [[(m, 0) for m in range(M)]] * N
    bwd = [[(m, 0) for m in range(M)]] * N
    return _ops_from_seqs("gpipe", M, N, 1, fwd, bwd, [M] * N)


def build_1f1b(M: int, N: int, *, double_warmup: bool = False) -> SchedPlan:
    """1F1B with warm-up ``N - n`` per device (``2(N-n) - 1`` when
    ``double_warmup`` — the FBP-AS / 1F1B-SO pipelining depth)."""
    fwd = [[(m, 0) for m in range(M)]] * N
    bwd = [[(m, 0) for m in range(M)]] * N
    warm = [2 * (N - n) - 1 if double_warmup else N - n for n in range(N)]
    name = "1f1b-2x" if double_warmup else "1f1b"
    return _ops_from_seqs(name, M, N, 1, fwd, bwd, warm)


def build_1f1b_interleaved(M: int, N: int, V: int) -> SchedPlan:
    """Streaming chunk passes (PR 1's circular-ppermute order): forward
    element ``e`` on every device is micro-batch ``e % M`` chunk
    ``e // M``; backwards mirror (last chunk first).  Warm-up must cover
    the full first V-1 passes plus the 1F1B ``N - n`` window, hence the
    ``(V-1)M`` resident-features term.  Requires ``M >= N`` so chunk
    passes stream through the ring without stalling."""
    if V < 1:
        raise ValueError(f"V must be >= 1, got {V}")
    if M < N:
        raise ValueError(f"1F1B-I needs M >= N to stream chunk passes "
                         f"(got M={M}, N={N})")
    MV = M * V
    fwd = [[(e % M, e // M) for e in range(MV)]] * N
    bwd = [[(e % M, V - 1 - e // M) for e in range(MV)]] * N
    warm = [(V - 1) * M + (N - n) for n in range(N)]
    return _ops_from_seqs("1f1b-interleaved", M, N, V, fwd, bwd, warm)


def build_dapple(M: int, N: int) -> SchedPlan:
    """DAPPLE early-backward schedule (arXiv 2007.01045): warm-up
    ``N - n`` forwards per device, then strict one-backward-one-forward
    alternation — the order that caps resident features at ``N - n``
    instead of GPipe's M.  The table coincides with synchronous 1F1B;
    it is a distinct builder so the runtime executes (and the tests pin)
    the early-backward order under its own name — derived from
    :func:`build_1f1b` so the two tables can never diverge."""
    return dataclasses.replace(build_1f1b(M, N), name="dapple")


def build_zb_h1(M: int, N: int) -> SchedPlan:
    """Zero-bubble H1 (arXiv 2211.05953): split every backward into an
    input-gradient op ``B`` (propagates the error to the previous stage)
    and a weight-gradient op ``W`` (no boundary edges, schedulable any
    time after its B).  Per device: warm-up ``N - n`` forwards, steady
    ``B, W, F`` cycles while forwards remain, then ``B, W`` drain pairs.

    With the even split ``b = w = B/2`` the drain gap between consecutive
    input-gradients (the downstream device's ``b + w``) is filled exactly
    by one W, so the bubble falls from 1F1B's ``(N-1)(F + B)`` to
    ``(N-1)(F + B/2)`` while peak resident features stay at ``N - n``
    (W directly follows its B, releasing the residual one op later)."""
    device_ops = []
    for n in range(N):
        mk = lambda kind, m: Op(kind, m, 0, n, N, 1)
        warm = max(1, min(M, N - n))
        ops = [mk("F", m) for m in range(warm)]
        nf, nb = warm, 0
        while nf < M:                       # steady: B, W, F
            ops += [mk("B", nb), mk("W", nb), mk("F", nf)]
            nb += 1
            nf += 1
        while nb < M:                       # drain: B, W pairs
            ops += [mk("B", nb), mk("W", nb)]
            nb += 1
        device_ops.append(tuple(ops))
    return SchedPlan(name="zb-h1", M=M, N=N, V=1,
                     device_ops=tuple(device_ops)).validate()


def zb_h2_mem_caps(M: int, N: int) -> list[int]:
    """ZB-H2's per-device peak-live row ``max(2(N-n)-1, n + ceil((N+1)/2))``
    — which is also the cap under which :func:`build_zb_auto` emits the
    ZB-H2 table.

    Two constraints meet: device n admits ``2(N-n) - 1`` warm-up forwards
    (double 1F1B's depth, so the error of micro-batch 0 arrives exactly
    when the deepened fill ends), and the zero-bubble *drain* needs the
    downstream devices to bank weight gradients past their last
    input-gradient — each hop the error travels upstream exposes ``F + b``
    of downstream wait that only postponed W ops can cover, growing the
    resident-residual count to ``n + ceil((N+1)/2)``.  Both are bounded by
    ``2N - 1``: the "~2x 1F1B warm-up memory" the zero-bubble paper
    (arXiv 2211.05953) quotes for ZB-H2."""
    return [max(1, min(M, max(2 * (N - n) - 1, n + (N + 2) // 2)))
            for n in range(N)]


def _normalize_caps(mem_limit, M: int, N: int) -> list[int]:
    """Resolve a ``mem_limit`` knob to per-device peak-live caps in
    [1, M]: falsy (None or 0) = unbounded, int = uniform, length-N
    sequence = per-device (0 entries = that device unbounded)."""
    if not mem_limit:
        caps = [M] * N
    elif isinstance(mem_limit, (int, float)):
        caps = [int(mem_limit)] * N
    else:
        caps = [int(c) or M for c in mem_limit]
        if len(caps) != N:
            raise ValueError(f"mem_limit needs one cap per device "
                             f"({N}), got {len(caps)}")
    return [max(1, min(M, c)) for c in caps]


def _replay_makespan(plan: SchedPlan, F_cs: Sequence[float],
                     B_cs: Sequence[float], W_cs: Sequence[float],
                     sr: Optional[Sequence[float]] = None) -> float:
    """Makespan of a fixed op table at per-device per-op costs
    (F, input-grad B, weight-grad W) — the discrete-event simulator's
    replay, with the full backward re-expressed as its per-device
    ``w_frac`` split and per-hop SR under the latency model (free comm
    when every hop is zero).  Imported lazily: the simulator imports
    this module at load time, but only calls back in here at run
    time."""
    from repro.core.simulator import simulate
    B_full = [b + w for b, w in zip(B_cs, W_cs)]
    wf = [w / bf for w, bf in zip(W_cs, B_full)]
    if sr is not None and any(s > 0 for s in sr):
        return simulate(plan, plan.M, plan.N, list(F_cs), B_full,
                        list(sr), w_frac=wf, comm="latency").makespan
    return simulate(plan, plan.M, plan.N, list(F_cs), B_full, 0.0,
                    w_frac=wf).makespan


def build_zb_auto(M: int, N: int, costs=(1.0, 1.0, 1.0),
                  mem_limit=None, *, name: str = "zb-auto") -> SchedPlan:
    """Automatic zero-bubble scheduler (arXiv 2211.05953's heuristic,
    adapted to the IR): an event-driven greedy list scheduler over F/B/W
    op placement that fills device idle slots with W ops subject to a
    per-device peak-live cap.

    Each device always has at most three candidate next ops — its next
    backward ``B`` (ready once its own F ran and the downstream error
    arrived), its next forward ``F`` (admissible only while the resident
    activation count is below the cap; the residual is released at W), and
    its oldest banked weight-gradient ``W`` (always startable).  The
    device picks the candidate with the earliest start time, breaking
    ties ``B > F > W`` — with one guard: once the next B's arrival time is
    known, an F or W is only admissible if it *fits entirely before* that
    arrival.  Errors are the critical path (every upstream device transits
    them), so the device would rather idle briefly than start a long op in
    front of an imminent backward; W ops are pure filler.  Devices commit
    ops in global start-time order, so the emitted per-device op list is
    exactly the order a work-conserving runtime would execute.

    ``costs`` is ``(F, B, W)`` — forward, input-gradient and
    weight-gradient durations (the closed forms' even split is
    ``B = W =`` half the full backward) — where each entry may be a
    scalar (uniform devices, today's interface) or a length-N sequence
    (heterogeneous devices), or a :class:`StageCosts` vector, whose
    per-hop ``SR`` then also delays cross-device arrivals (latency
    model), so the emitted table is genuinely *cost-shaped*: the greedy
    sees each device's real F/B/W and each boundary's real transfer
    time when it decides what fits before the next backward.  Uniform
    vectors reproduce the scalar interface's tables exactly (pinned).
    ``mem_limit`` is the peak-live cap: ``None``/``0`` (unbounded: peak
    climbs to M while every bubble after the fill ramp vanishes), an
    int (uniform), or a length-N sequence.
    The cap reproduces the hand-written tables as special cases — the
    1F1B window ``N - n`` yields exactly :func:`build_zb_h1`'s table, and
    :func:`zb_h2_mem_caps` yields ZB-H2 (:func:`build_zb_h2`) — pinned in
    ``tests/test_schedplan_properties.py``.

    A greedy list scheduler can still lose to a hand-written order at
    adversarial cost ratios, so the builder ends with a portfolio step:
    whenever the ZB-H1 table fits the cap, both tables are replayed at
    ``costs`` and the cheaper one is returned (ties keep the greedy, so
    the special-case reproductions above are exact table equalities).
    That makes ``zb-auto <= zb-h1`` *structural* for any cap that admits
    the 1F1B window — the property the randomized differential sweep in
    ``tests/test_simulator_vs_closed_form.py`` pins.  For heterogeneous
    vectors a second portfolio member is the table the *scalar collapse*
    ``(max F, max B, max W)`` would have built, replayed at the true
    vector costs — so ``zb-auto(vector) <= zb-auto(max-scalar)`` is
    structural too (the cost-shaped table can only win)."""
    if isinstance(costs, StageCosts):
        if costs.n != N:
            raise ValueError(f"costs are for {costs.n} devices, "
                             f"build_zb_auto was asked for N={N}")
        F_cs, B_cs, W_cs = list(costs.F), list(costs.B), list(costs.W)
        sr = list(costs.sr_hops)
    else:
        F_c, B_c, W_c = costs
        F_cs = _cost_vec(F_c, N, "zb-auto F costs")
        B_cs = _cost_vec(B_c, N, "zb-auto B costs")
        W_cs = _cost_vec(W_c, N, "zb-auto W costs")
        sr = [0.0] * max(0, N - 1)
    if any(c <= 0 for c in F_cs + B_cs + W_cs):
        raise ValueError(f"zb-auto op costs must be positive, got {costs}")
    hetero = (len(set(F_cs)) > 1 or len(set(B_cs)) > 1
              or len(set(W_cs)) > 1)
    caps = _normalize_caps(mem_limit, M, N)
    f_done = [[None] * N for _ in range(M)]
    b_done = [[None] * N for _ in range(M)]
    dev_free = [0.0] * N
    nf = [0] * N                    # next F micro-batch per device
    nb = [0] * N                    # next B micro-batch per device
    nw = [0] * N                    # next W micro-batch per device
    live = [0] * N                  # resident activations (F issued, W not)
    ops: list[list[Op]] = [[] for _ in range(N)]
    makespan = 0.0
    eps = 1e-9
    for _ in range(3 * M * N):
        best = None                 # (start, prio, device, kind)
        for n in range(N):
            cands = []
            t_b = None              # known start of the next backward
            m = nb[n]
            if m < M and f_done[m][n] is not None:
                if n == N - 1:
                    arr = f_done[m][n]
                else:
                    arr = b_done[m][n + 1]
                    if arr is not None:
                        arr += sr[n]
                if arr is not None:
                    t_b = max(dev_free[n], arr)
                    cands.append((t_b, 0, "B"))
            m = nf[n]
            if m < M and live[n] < caps[n]:
                arr = 0.0 if n == 0 else f_done[m][n - 1]
                if arr is not None:
                    if n > 0:
                        arr += sr[n - 1]
                    s = max(dev_free[n], arr)
                    if t_b is None or s + F_cs[n] <= t_b + eps:
                        cands.append((s, 1, "F"))
            if nw[n] < nb[n]:
                s = dev_free[n]
                # the fits-before-B guard is waived when the cap binds: a
                # W then gates the next F admission (it releases the
                # residual slot), so it is on the forward-supply critical
                # path, not filler
                if (t_b is None or s + W_cs[n] <= t_b + eps
                        or (nf[n] < M and live[n] >= caps[n])):
                    cands.append((s, 2, "W"))
            if cands:
                s, p, k = min(cands)
                if best is None or (s, p, n) < best[:3]:
                    best = (s, p, n, k)
        assert best is not None, "zb-auto scheduler stalled (internal bug)"
        s, _, n, kind = best
        if kind == "F":
            m = nf[n]
            end = s + F_cs[n]
            f_done[m][n] = end
            nf[n] += 1
            live[n] += 1
        elif kind == "B":
            m = nb[n]
            end = s + B_cs[n]
            b_done[m][n] = end
            nb[n] += 1
        else:
            m = nw[n]
            end = s + W_cs[n]
            nw[n] += 1
            live[n] -= 1
        dev_free[n] = end
        makespan = max(makespan, end)
        ops[n].append(Op(kind, m, 0, n, N, 1))
    plan = SchedPlan(name=name, M=M, N=N, V=1,
                     device_ops=tuple(tuple(o) for o in ops)).validate()
    # portfolio step: never lose to the hand-written ZB-H1 order when it
    # fits the cap (strict improvement required, so exact special-case
    # reproductions keep the greedy's table)
    h1 = build_zb_h1(M, N)
    if all(p <= c for p, c in zip(h1.peak_live(), caps)):
        h1_ms = _replay_makespan(h1, F_cs, B_cs, W_cs, sr)
        if h1_ms < makespan - 1e-12:
            plan = dataclasses.replace(h1, name=name)
            makespan = h1_ms
    # heterogeneous portfolio step: the table the legacy scalar collapse
    # (max F, max B, max W) would have built, replayed at the TRUE vector
    # costs — makes zb-auto(vector) <= zb-auto(max-scalar) structural
    # (again strict, so uniform vectors keep the greedy's table)
    if hetero:
        scal = build_zb_auto(M, N, costs=(max(F_cs), max(B_cs), max(W_cs)),
                             mem_limit=mem_limit)
        if _replay_makespan(scal, F_cs, B_cs, W_cs, sr) < makespan - 1e-12:
            plan = dataclasses.replace(scal, name=name)
    return plan


def build_zb_h2(M: int, N: int) -> SchedPlan:
    """Zero-bubble H2 (arXiv 2211.05953): the bubble-free hand-crafted
    point — warm-up ``2(N-n) - 1`` forwards (double 1F1B's pipelining
    depth) and weight-gradients banked past the drain downstream, so
    after the unavoidable ``(N-1)F`` fill ramp the makespan-carrying
    device never idles: makespan ``M(F+B) + (N-1)F`` (the whole
    ``(N-1)(F + B)`` 1F1B flush bubble is gone) at
    ``max(2(N-n)-1, n + ceil((N+1)/2))`` resident activations
    (:func:`zb_h2_mem_caps`) — the "~2x 1F1B memory" trade.  Derived as
    the :func:`build_zb_auto` table under that cap at unit costs, so H2
    *is* a special case of the automatic scheduler's cap."""
    return dataclasses.replace(
        build_zb_auto(M, N, mem_limit=zb_h2_mem_caps(M, N)), name="zb-h2")


def build_1f1b_interleaved_memlean(M: int, N: int, V: int) -> SchedPlan:
    """Megatron-style memory-lean interleaved 1F1B: micro-batches advance
    in groups of N, cycling the V chunks inside each group, with warm-up
    ``2(N - n - 1) + (V-1)N``.  Peak resident features fall from
    ``(V-1)M + N - n`` (streaming) to ``2(N - n - 1) + (V-1)N`` while the
    makespan is unchanged.  Requires ``M % N == 0`` (Megatron's
    constraint): with group size N, micro-batch m's pass v+1 is issued
    exactly N elements after pass v, which is also the tick count for the
    ring return to travel the daisy chain back to stage 0."""
    if V < 1:
        raise ValueError(f"V must be >= 1, got {V}")
    if M < N or M % N != 0:
        raise ValueError(
            f"1f1b-interleaved-memlean needs M % N == 0 (micro-batch "
            f"groups of the pipeline depth), got M={M}, N={N}")
    fwd_seq = [(g * N + r, v)
               for g in range(M // N) for v in range(V) for r in range(N)]
    bwd_seq = [(g * N + r, V - 1 - vv)
               for g in range(M // N) for vv in range(V) for r in range(N)]
    fwd = [fwd_seq] * N
    bwd = [bwd_seq] * N
    # Megatron counts warm-up forwards before the first steady-state
    # *forward* (F-then-B iterations); our skeleton alternates B-first, so
    # its warm-up is one deeper.  Peak resident features are identical:
    # 2(N-n-1) + (V-1)N + 1.
    warm = [2 * (N - n - 1) + (V - 1) * N + 1 for n in range(N)]
    return _ops_from_seqs("1f1b-interleaved-memlean", M, N, V, fwd, bwd, warm)


# canonical builder names + legacy schedule-table aliases -------------------
_ALIASES = {
    "gpipe": ("gpipe", {}),
    "1f1b": ("1f1b", {}),
    "1f1b-2x": ("1f1b", {"double_warmup": True}),
    "1f1b-interleaved": ("1f1b-interleaved", {}),
    "1f1b-interleaved-memlean": ("1f1b-interleaved-memlean", {}),
    "dapple": ("dapple", {}),
    "zb-h1": ("zb-h1", {}),
    "zb_h1": ("zb-h1", {}),
    "zb-h2": ("zb-h2", {}),
    "zb_h2": ("zb-h2", {}),
    "zb-auto": ("zb-auto", {}),
    "zb_auto": ("zb-auto", {}),
    # legacy closed-form/simulator names
    "1F1B-AS": ("1f1b", {}),
    "1F1B-SNO": ("1f1b", {}),
    "FBP-AS": ("1f1b", {"double_warmup": True}),
    "1F1B-SO": ("1f1b", {"double_warmup": True}),
    "1F1B-I": ("1f1b-interleaved", {}),
    "1F1B-I-ML": ("1f1b-interleaved-memlean", {}),
    "DAPPLE": ("dapple", {}),
    "ZB-H1": ("zb-h1", {}),
    "ZB-H2": ("zb-h2", {}),
    "ZB-AUTO": ("zb-auto", {}),
}

_BUILDERS = {
    "gpipe": lambda M, N, V, **kw: build_gpipe(M, N),
    "1f1b": lambda M, N, V, **kw: build_1f1b(M, N, **kw),
    "1f1b-interleaved": lambda M, N, V, **kw: build_1f1b_interleaved(M, N, V),
    "1f1b-interleaved-memlean":
        lambda M, N, V, **kw: build_1f1b_interleaved_memlean(M, N, V),
    "dapple": lambda M, N, V, **kw: build_dapple(M, N),
    "zb-h1": lambda M, N, V, **kw: build_zb_h1(M, N),
    "zb-h2": lambda M, N, V, **kw: build_zb_h2(M, N),
    "zb-auto": lambda M, N, V, **kw: build_zb_auto(M, N, **kw),
}

INTERLEAVED = ("1f1b-interleaved", "1f1b-interleaved-memlean")

#: every canonical builder name (the conformance suite sweeps these)
BUILDER_NAMES = ("gpipe", "1f1b", "dapple", "zb-h1", "zb-h2", "zb-auto",
                 "1f1b-interleaved", "1f1b-interleaved-memlean")


def canonical_name(name: str) -> str:
    """Map a legacy schedule-table name (or canonical name) to the
    canonical builder name."""
    if name not in _ALIASES:
        raise ValueError(f"unknown schedule {name!r}")
    return _ALIASES[name][0]


def add_grad_sync(plan: SchedPlan, groups: int = 1) -> SchedPlan:
    """Append the data-parallel gradient-sync AR ops to a compute plan:
    ``groups`` AR buckets per (device, chunk) parameter bucket, issued
    after the device's compute drains, earliest-retired bucket first.
    The bucket for chunk v is ready the moment its last B/W retires —
    per-stage readiness, so stage N-1 (whose backward chain finishes
    first) syncs earliest and stage 0 last; the tick assignment then
    packs the AR slots into the remaining drain ticks, one bucket in
    flight at a time on the shared data-axis fabric (see
    ``_assign_ticks``).

    ``groups > 1`` splits each chunk bucket into per-layer-group
    sub-buckets (``op.m`` carries the group index): the trailing
    backward produces layer-group gradients progressively in reverse
    layer order, so group g's slice is final a ``(groups - 1 - g) /
    groups`` fraction of the final retiring op EARLY — the sub-release
    model :func:`repro.core.schedules.eval_grad_sync` prices.  At the
    tick level the sub-buckets still issue after the chunk's last B/W
    (a tick cannot start mid-op); what the finer grain buys is smaller
    fabric quanta that interleave across devices' drains and, on real
    hardware, collectives launched as each group retires."""
    if plan.has_grad_sync:
        if plan.grad_sync_groups != groups:
            raise ValueError(
                f"{plan.name} already carries {plan.grad_sync_groups} "
                f"grad-sync groups; asked for {groups}")
        return plan
    if groups < 1:
        raise ValueError(f"groups must be >= 1, got {groups}")
    release = "W" if plan.has_w else "B"
    device_ops = []
    for n, ops in enumerate(plan.device_ops):
        last_release = {}
        for i, op in enumerate(ops):
            if op.kind == release:
                last_release[op.v] = i
        order = sorted(last_release, key=last_release.get)
        ars = tuple(Op("AR", g, v, n, plan.N, plan.V)
                    for v in order for g in range(groups))
        device_ops.append(tuple(ops) + ars)
    return dataclasses.replace(
        plan, device_ops=tuple(device_ops)).validate()


def build_schedule(name: str, M: int, N: int, V: int = 1,
                   mem_limit=None,
                   grad_sync: Union[bool, int] = False) -> SchedPlan:
    """Build the op table for a schedule by canonical or legacy name.
    ``mem_limit`` is the automatic zero-bubble scheduler's peak-live cap
    (``zb-auto`` only: None = unbounded, int = uniform, sequence =
    per-device); other schedules' memory behaviour is fixed by their
    table and the knob is rejected.  ``grad_sync=True`` appends the
    data-parallel gradient-sync AR ops (:func:`add_grad_sync`) so the
    sync is scheduled into the drain instead of paid after it; an
    integer > 1 splits each bucket into that many per-layer-group
    sub-buckets."""
    builder, kw = _ALIASES.get(name, (None, None))
    if builder is None:
        raise ValueError(name)
    if V != 1 and canonical_name(name) not in INTERLEAVED:
        raise ValueError(f"V={V} only supported for interleaved schedules "
                         f"(got {name})")
    if mem_limit is not None:
        if builder != "zb-auto":
            raise ValueError(f"mem_limit only applies to zb-auto "
                             f"(got {name})")
        kw = dict(kw, mem_limit=mem_limit)
    plan = _BUILDERS[builder](M, N, V, **kw)
    if grad_sync:
        return add_grad_sync(plan,
                             groups=grad_sync if grad_sync is not True
                             else 1)
    return plan


def resolve_ring_schedule(schedule: str, V: int) -> str:
    """Resolve the runtime's ``PipelineConfig.schedule`` to a canonical
    builder name: ``auto`` keeps PR 1's behaviour (plain 1F1B ring for
    V == 1, streaming interleave for V > 1)."""
    if schedule in ("auto", "", None):
        return "1f1b" if V == 1 else "1f1b-interleaved"
    name = canonical_name(schedule)
    if V > 1 and name not in INTERLEAVED:
        raise ValueError(f"schedule {schedule!r} cannot run virtual={V} "
                         f"chunks; pick an interleaved schedule")
    return name


# ---------------------------------------------------------------------------
# Closed-form resident-features counts (validated against peak_live()).
# ---------------------------------------------------------------------------

def live_activation_counts(name: str, M: int, N: int, V: int = 1,
                           feat_mult: int = 1, mem_limit=None) -> list[int]:
    """Per-device peak resident chunk-activation counts — the algebraic
    form of :meth:`SchedPlan.peak_live`, O(1) per device so the explorer
    can sweep huge M without materialising tables.  ``feat_mult`` doubles
    the 1F1B window (FBP-AS / 1F1B-SO); ``mem_limit`` is the zb-auto
    peak-live cap (None = unbounded, where the cost of a fully bubble-free
    schedule is GPipe-like M resident activations).  Differentially tested
    against the symbolic replay in ``tests/test_schedplan.py``."""
    cname = canonical_name(name)
    caps = _normalize_caps(mem_limit, M, N) if cname == "zb-auto" else None
    out = []
    for n in range(N):
        if cname == "gpipe":
            w = M * V
        elif cname == "1f1b":
            # feat_mult=2 is the doubled-warm-up window (FBP-AS/1F1B-SO);
            # the symbolic replay gives 2(N-n)-1, the schedule tables round
            # up to 2(N-n) — kept here so partition.stage_memory is
            # bit-identical to the pre-IR arithmetic.
            w = feat_mult * (N - n)
        elif cname in ("dapple", "zb-h1"):
            # dapple == synchronous 1F1B; ZB-H1 keeps the same warm-up and
            # its W directly follows each B, so both hold the 1F1B window
            w = N - n
        elif cname == "zb-h2":
            # deep warm-up upstream, postponed weight-grads downstream
            # (see zb_h2_mem_caps)
            w = max(2 * (N - n) - 1, n + (N + 2) // 2)
        elif cname == "zb-auto":
            # the greedy fills to its cap (unbounded: every residual is
            # held until the drain's W sweep, so the row is M)
            w = caps[n]
        elif cname == "1f1b-interleaved":
            w = (V - 1) * M + (N - n)
        else:                          # 1f1b-interleaved-memlean
            w = 2 * (N - n - 1) + (V - 1) * N + 1
        out.append(max(1, min(M * V, w)))
    return out


# ---------------------------------------------------------------------------
# Ring lowering: compile the forward order into the tick-scan runtime's
# lookup tables.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RingLowering:
    """Per-element lookup tables for the synchronous tick-scan runtime.

    The runtime runs ``n_ticks = M*V + N - 1`` ticks; at tick t, device s
    processes forward element ``e = t - s`` of the shared per-device
    forward sequence (every device issues the same sequence, shifted by
    its stage index — a property :func:`lower_to_ring` verifies).  All
    arrays have length M*V and are indexed by e:

    * ``m_of_e`` / ``v_of_e`` — micro-batch and chunk of element e.
    * ``fresh``   — stage 0 injects fresh data (chunk-0 pass) at e.
    * ``direct``  — element e's input is the ring return arriving this
      very tick (produced by the last stage exactly N ticks earlier), so
      it is consumed straight off the ppermute carry — no park buffer.
    * ``park``    — the ring return of element e must be parked in the
      stage-0 return buffer (slot ``m_of_e[e]``) until its next pass.
    * ``collect`` — element e's output on the last stage is a final
      (chunk V-1) output, written to ``outbuf[m_of_e[e]]``.

    ``needs_retbuf`` is False exactly when every chunk handoff is direct —
    true for the memlean order (and for streaming when M == N), which is
    what deletes the ``[M, ...]`` micro-batch return buffer from the scan
    carry.
    """
    schedule: str
    M: int
    N: int
    V: int
    m_of_e: tuple[int, ...]
    v_of_e: tuple[int, ...]
    fresh: tuple[bool, ...]
    direct: tuple[bool, ...]
    park: tuple[bool, ...]
    collect: tuple[bool, ...]

    @property
    def n_ticks(self) -> int:
        return self.M * self.V + self.N - 1

    @property
    def needs_retbuf(self) -> bool:
        return any(self.park)


def lower_to_ring(plan: SchedPlan) -> RingLowering:
    """Lower a schedule plan onto the circular-``ppermute`` runtime.

    Validates that the plan is ring-executable:

    1. every device issues the same forward (m, v) sequence (device n's
       element e runs at tick e + n);
    2. chunk pass v+1 of a micro-batch is issued at least N elements
       after pass v, so its ring return (which takes exactly N ticks to
       travel stage 0 -> ... -> stage N-1 -> stage 0) has arrived.
    """
    M, N, V = plan.M, plan.N, plan.V
    seq0 = plan.forward_sequence(0)
    for n in range(1, N):
        if plan.forward_sequence(n) != seq0:
            raise ValueError(
                f"{plan.name}: devices disagree on the forward issue "
                f"order; not executable on the synchronous ring")
    index_of = {mv: e for e, mv in enumerate(seq0)}
    MV = M * V
    m_of_e = tuple(m for m, _ in seq0)
    v_of_e = tuple(v for _, v in seq0)
    fresh = tuple(v == 0 for v in v_of_e)
    direct = [False] * MV
    park = [False] * MV
    for e, (m, v) in enumerate(seq0):
        if v == 0:
            continue
        prev = index_of[(m, v - 1)]
        gap = e - prev
        if gap < N:
            raise ValueError(
                f"{plan.name}: pass {v} of micro-batch {m} issued only "
                f"{gap} elements after pass {v - 1}; the ring return "
                f"needs {N} ticks (M={M}, N={N}, V={V})")
        if gap == N:
            direct[e] = True
        else:
            park[prev] = True
    collect = tuple(v == V - 1 for v in v_of_e)
    return RingLowering(schedule=plan.name, M=M, N=N, V=V,
                        m_of_e=m_of_e, v_of_e=v_of_e, fresh=fresh,
                        direct=tuple(direct), park=tuple(park),
                        collect=collect)


# ---------------------------------------------------------------------------
# Tick lowering: compile the FULL mixed F/B(/W) table into the training
# runtime's per-device per-tick lookup arrays.
# ---------------------------------------------------------------------------

# op-kind codes of the tick tables (the runtime's lax.switch branch index;
# TICK_AR is not a switch branch — the stream runtime runs the bucket
# reduce-scatter/all-gather outside the switch, gated per slot)
TICK_IDLE, TICK_F, TICK_B, TICK_B_SEED, TICK_W, TICK_AR = range(6)


@dataclasses.dataclass(frozen=True)
class TickLowering:
    """Per-device per-tick lookup tables for the mixed F/B(/W) tick scan.

    The runtime runs ``n_ticks`` synchronous ticks; at tick t, device n
    executes op ``kind[n][t]`` on micro-batch ``m[n][t]`` chunk
    ``v[n][t]``.  Stage-boundary transfers are one-tick neighbour hops on
    two ppermute rings (forward ``n -> n+1``, backward ``n -> n-1``); an
    arrival the consuming op is not ready for is parked into a statically
    allocated inbox slot.  All buffers are register-allocated from the op
    table, so the residual stash size ``n_x`` IS the schedule's peak-live
    row — the runtime's memory follows the IR's features-memory claim by
    construction.

    Tables (each ``[N][n_ticks]``; -1 = not applicable this tick):

    * ``kind``  — TICK_IDLE / TICK_F / TICK_B / TICK_B_SEED / TICK_W /
      TICK_AR.  TICK_B_SEED sits on the last virtual stage: its
      cotangent is seeded by the per-micro-batch loss head, not the
      ring.  TICK_AR (grad-sync plans only) marks the tick a device's
      chunk-``v`` gradient bucket crosses the data-axis fabric; it is
      not a compute branch — ``m`` is 0 and ``v`` is the bucket.
    * ``m`` / ``v`` — micro-batch and chunk of the tick's op.
    * ``xw`` — residual-stash slot an F writes its stage input to.
    * ``xr`` — residual-stash slot a B/W reads (released by the last
      reader: B for two-op plans, W for zero-bubble plans).
    * ``fsrc`` — F input source: 0 fresh injection (stage 0, chunk-0
      pass), 1 the forward ring carry arriving this very tick, 2 a
      forward-inbox slot (``fr``).
    * ``fpark`` — forward-inbox slot the tick's *arriving* forward carry
      must be parked into (independent of the device's own op).
    * ``bsrc`` / ``br`` / ``bpark`` — same for backward cotangents
      (0 = loss-seeded, never read from the ring).
    * ``cw`` / ``cr`` — zero-bubble cotangent stash: a B stores its
      output-cotangent for the matching W (``cw``: the ring error, or —
      on the seeded last virtual stage — the loss head's y-cotangent);
      the W reads it back (``cr``).
    * ``dinj`` — True where a B's input-cotangent is the gradient of the
      fresh injection (virtual stage 0): written to the d_inj buffer for
      the embedding backward instead of the ring.
    """
    schedule: str
    M: int
    N: int
    V: int
    n_ticks: int
    has_w: bool
    kind: tuple[tuple[int, ...], ...]
    m: tuple[tuple[int, ...], ...]
    v: tuple[tuple[int, ...], ...]
    xw: tuple[tuple[int, ...], ...]
    xr: tuple[tuple[int, ...], ...]
    fsrc: tuple[tuple[int, ...], ...]
    fr: tuple[tuple[int, ...], ...]
    fpark: tuple[tuple[int, ...], ...]
    bsrc: tuple[tuple[int, ...], ...]
    br: tuple[tuple[int, ...], ...]
    bpark: tuple[tuple[int, ...], ...]
    cw: tuple[tuple[int, ...], ...]
    cr: tuple[tuple[int, ...], ...]
    dinj: tuple[tuple[bool, ...], ...]
    n_x: int
    n_f: int
    n_b: int
    n_c: int


def _assign_ticks(plan: SchedPlan):
    """Greedy in-order synchronous scheduling: at each tick every device
    runs its next op if the op's inputs were produced at a strictly
    earlier tick (one-tick neighbour hops), else stalls.  Returns
    (f_tick, b_tick, w_tick, ar_tick, n_ticks) keyed by (m, vstage).

    AR (gradient-sync) ops ride the shared data-axis fabric: at most
    one bucket is in flight per tick across ALL devices (every stage
    group's all-reduce crosses the same data-axis links — DAPPLE's
    contention argument), so a ready AR stalls while another device's
    bucket occupies the fabric.  Devices are scanned highest-first so
    stage N-1 — whose backward chain drains first — wins fabric ties;
    the scan order cannot change F/B/W placement because an op placed
    at tick t never enables another op at the same tick (all readiness
    tests are against strictly earlier ticks)."""
    M, N, NS = plan.M, plan.N, plan.N * plan.V
    f_tick: dict = {}
    b_tick: dict = {}
    w_tick: dict = {}
    ar_tick: dict = {}
    ptr = [0] * N
    total = sum(len(ops) for ops in plan.device_ops)
    placed = 0
    t = 0
    while placed < total:
        progressed = False
        fabric_used = False
        for n in reversed(range(N)):
            if ptr[n] >= len(plan.device_ops[n]):
                continue
            op = plan.device_ops[n][ptr[n]]
            key = (op.m, op.vstage)
            if op.kind == "F":
                ok = op.vstage == 0 or (
                    (op.m, op.vstage - 1) in f_tick
                    and f_tick[(op.m, op.vstage - 1)] + 1 <= t)
            elif op.kind == "B":
                if op.vstage == NS - 1:
                    ok = key in f_tick and f_tick[key] + 1 <= t
                else:
                    ok = (key in f_tick
                          and (op.m, op.vstage + 1) in b_tick
                          and b_tick[(op.m, op.vstage + 1)] + 1 <= t)
            elif op.kind == "W":        # W: any time after its own B
                ok = key in b_tick and b_tick[key] + 1 <= t
            else:                       # AR: bucket retired (in-order
                ok = not fabric_used    # ptr) + data fabric free
            if ok:
                tick_of = {"F": f_tick, "B": b_tick,
                           "W": w_tick, "AR": ar_tick}[op.kind]
                tick_of[key] = t
                if op.kind == "AR":
                    fabric_used = True
                ptr[n] += 1
                placed += 1
                progressed = True
        if not progressed:
            raise ValueError(
                f"{plan.name}: tick lowering deadlocked at tick {t} with "
                f"{total - placed} ops unplaced (pointers {ptr}) — the op "
                f"table has a cyclic cross-device dependency")
        t += 1
    return f_tick, b_tick, w_tick, ar_tick, t


def _alloc_slots(intervals):
    """Linear-scan register allocation of [start, end]-inclusive lifetime
    intervals onto the fewest slots (a slot is reusable from end+1).
    Returns ({key: slot}, n_slots)."""
    import heapq
    out: dict = {}
    free: list = []
    inuse: list = []
    n_slots = 0
    for start, end, key in sorted(intervals):
        while inuse and inuse[0][0] < start:
            heapq.heappush(free, heapq.heappop(inuse)[1])
        slot = heapq.heappop(free) if free else n_slots
        n_slots = max(n_slots, slot + 1)
        out[key] = slot
        heapq.heappush(inuse, (end, slot))
    return out, n_slots


def lower_to_ticks(plan: SchedPlan) -> TickLowering:
    """Compile the full mixed F/B(/W) op table onto the synchronous
    two-ring runtime (see :class:`TickLowering`)."""
    M, N, V = plan.M, plan.N, plan.V
    NS = N * V
    has_w = plan.has_w
    f_tick, b_tick, w_tick, ar_tick, n_ticks = _assign_ticks(plan)
    release = w_tick if has_w else b_tick

    def dev_of(vs: int) -> int:
        return vs % N

    # --- per-device slot allocation ------------------------------------
    n_x = n_f = n_b = n_c = 0
    xslot: dict = {}
    fslot: dict = {}
    bslot: dict = {}
    cslot: dict = {}
    for n in range(N):
        xs = [(f_tick[k], release[k], k) for k in f_tick
              if dev_of(k[1]) == n]
        s, c = _alloc_slots(xs)
        xslot.update(s)
        n_x = max(n_x, c)
        fs = []
        for (m, vs), t in f_tick.items():
            if dev_of(vs) != n or vs == 0:
                continue
            arr = f_tick[(m, vs - 1)] + 1
            if arr < t:                      # not consumed on arrival
                fs.append((arr, t, (m, vs)))
        s, c = _alloc_slots(fs)
        fslot.update(s)
        n_f = max(n_f, c)
        bs = []
        for (m, vs), t in b_tick.items():
            if dev_of(vs) != n or vs == NS - 1:
                continue
            arr = b_tick[(m, vs + 1)] + 1
            if arr < t:
                bs.append((arr, t, (m, vs)))
        s, c = _alloc_slots(bs)
        bslot.update(s)
        n_b = max(n_b, c)
        if has_w:
            cs = [(b_tick[k], w_tick[k], k) for k in b_tick
                  if dev_of(k[1]) == n]
            s, c = _alloc_slots(cs)
            cslot.update(s)
            n_c = max(n_c, c)

    # --- table emission -------------------------------------------------
    def tab(fill):
        return [[fill] * n_ticks for _ in range(N)]

    kind = tab(TICK_IDLE)
    m_t = tab(0)
    v_t = tab(0)
    xw = tab(-1)
    xr = tab(-1)
    fsrc = tab(0)
    fr = tab(-1)
    fpark = tab(-1)
    bsrc = tab(0)
    br = tab(-1)
    bpark = tab(-1)
    cw = tab(-1)
    cr = tab(-1)
    dinj = tab(False)

    for (m, vs), t in f_tick.items():
        n = dev_of(vs)
        kind[n][t] = TICK_F
        m_t[n][t] = m
        v_t[n][t] = vs // N
        xw[n][t] = xslot[(m, vs)]
        if vs == 0:
            fsrc[n][t] = 0
        elif (m, vs) in fslot:
            fsrc[n][t] = 2
            fr[n][t] = fslot[(m, vs)]
            fpark[n][f_tick[(m, vs - 1)] + 1] = fslot[(m, vs)]
        else:
            fsrc[n][t] = 1
    for (m, vs), t in b_tick.items():
        n = dev_of(vs)
        seed = vs == NS - 1
        kind[n][t] = TICK_B_SEED if seed else TICK_B
        m_t[n][t] = m
        v_t[n][t] = vs // N
        xr[n][t] = xslot[(m, vs)]
        if not seed:
            if (m, vs) in bslot:
                bsrc[n][t] = 2
                br[n][t] = bslot[(m, vs)]
                bpark[n][b_tick[(m, vs + 1)] + 1] = bslot[(m, vs)]
            else:
                bsrc[n][t] = 1
        if has_w:
            cw[n][t] = cslot[(m, vs)]
        if vs == 0:
            dinj[n][t] = True
    for (m, vs), t in w_tick.items():
        n = dev_of(vs)
        kind[n][t] = TICK_W
        m_t[n][t] = m
        v_t[n][t] = vs // N
        xr[n][t] = xslot[(m, vs)]
        cr[n][t] = cslot[(m, vs)]
    for (m, vs), t in ar_tick.items():
        n = dev_of(vs)
        kind[n][t] = TICK_AR
        m_t[n][t] = m
        v_t[n][t] = vs // N

    frz = lambda rows: tuple(tuple(r) for r in rows)
    return TickLowering(
        schedule=plan.name, M=M, N=N, V=V, n_ticks=n_ticks, has_w=has_w,
        kind=frz(kind), m=frz(m_t), v=frz(v_t), xw=frz(xw), xr=frz(xr),
        fsrc=frz(fsrc), fr=frz(fr), fpark=frz(fpark),
        bsrc=frz(bsrc), br=frz(br), bpark=frz(bpark),
        cw=frz(cw), cr=frz(cr), dinj=frz(dinj),
        n_x=n_x, n_f=n_f, n_b=n_b, n_c=n_c)


# ---------------------------------------------------------------------------
# Instruction lowering: compile the op tables into decentralized
# per-device instruction streams (RUN / SEND / RECV / FREE).
# ---------------------------------------------------------------------------

# instruction opcodes (the Alpa-style decentralized runtime vocabulary);
# ARSYNC is the bucketed data-parallel gradient reduce-scatter/all-gather
INSTR_RUN, INSTR_SEND, INSTR_RECV, INSTR_FREE, INSTR_AR = range(5)

_INSTR_NAMES = ("RUN", "SEND", "RECV", "FREE", "ARSYNC")


@dataclasses.dataclass(frozen=True)
class Instr:
    """One instruction of a device's stream.

    * ``RUN``  — execute op ``kind`` (TICK_F/TICK_B/TICK_B_SEED/TICK_W)
      on micro-batch ``m``, chunk ``v``.
    * ``SEND`` — put the op's output on ``ring`` ("fwd"/"bwd"); issued
      asynchronously (collective-start), the matching shift happens at
      the slot boundary.
    * ``RECV`` — take an arriving value off ``ring``: into inbox slot
      ``idx`` (parked, consumed by a later RUN) or straight into the
      consuming RUN (``idx == -1``, the value is used the slot it lands).
    * ``FREE`` — release register ``idx`` of buffer ``buf`` ("x" residual
      stash, "f"/"b" forward/backward inbox, "c" zero-bubble cotangent):
      the allocator may now reuse it.
    * ``ARSYNC`` — reduce-scatter + all-gather chunk ``v``'s gradient
      bucket over the ``data`` mesh axis (grad-sync plans only); one
      bucket in flight per slot across all devices.

    ``slot`` is the global program-counter value the instruction executes
    at — devices with shorter streams simply have no instructions at
    some slots (they neither compute nor touch a ring there).
    """
    op: int
    slot: int
    kind: int = TICK_IDLE
    m: int = -1
    v: int = -1
    ring: str = ""
    buf: str = ""
    idx: int = -1

    def __repr__(self):
        core = f"{_INSTR_NAMES[self.op]}@{self.slot}"
        if self.op == INSTR_RUN:
            k = ("IDLE", "F", "B", "Bseed", "W", "AR")[self.kind]
            return f"{core} {k}(m={self.m}, v={self.v})"
        if self.op == INSTR_AR:
            return f"{core} bucket(v={self.v})"
        if self.op in (INSTR_SEND, INSTR_RECV):
            tgt = "direct" if self.idx < 0 else f"inbox[{self.idx}]"
            return (f"{core} {self.ring}" +
                    (f" -> {tgt}" if self.op == INSTR_RECV else ""))
        return f"{core} {self.buf}[{self.idx}]"


@dataclasses.dataclass(frozen=True)
class InstrLowering:
    """Decentralized per-device instruction streams plus the compiled
    slot program the SPMD runtime executes.

    ``streams[n]`` is device n's own program: RUN ops back to back with
    explicit SEND/RECV ring touches and FREE register releases — no
    global tick grid.  ``ticks`` is the same program compiled onto a
    shared slot counter (the only clock a single-program ``lax.scan``
    has): slot ``j`` of every stream executes at scan iteration ``j``,
    and the two rings shift ONLY at slots where some device SENDs
    (``fsend``/``bsend``) — every other slot has no collective at all,
    so devices drift through their own op durations between comm points
    instead of barriering twice per tick.  Buffers are inherited from
    the tick lowering's register allocation, i.e. still sized by
    ``peak_live()``.

    ``slot_of`` maps ``(kind, m, vstage)`` (kind "F"/"B"/"W"/"AR") to
    the op's slot — the execution order the differential tests compare
    against the discrete-event simulator's event order.

    ``arsync[j]`` is True when ANY device runs an ARSYNC at slot j (at
    most one does — the shared-fabric rule): the runtime's per-slot
    gate on the gradient-bucket collective, uniform across the mesh
    like ``fsend``/``bsend``.
    """
    ticks: TickLowering
    streams: tuple[tuple[Instr, ...], ...]
    fsend: tuple[bool, ...]
    bsend: tuple[bool, ...]
    slot_of: dict
    arsync: tuple[bool, ...] = ()

    @property
    def schedule(self) -> str:
        return self.ticks.schedule

    @property
    def n_slots(self) -> int:
        return self.ticks.n_ticks

    @property
    def has_w(self) -> bool:
        return self.ticks.has_w

    @property
    def n_shifts(self) -> int:
        """Ring shifts actually scheduled (the tick runtime pays
        ``2 * n_ticks``)."""
        return sum(self.fsend) + sum(self.bsend)


def lower_to_instructions(plan: SchedPlan) -> InstrLowering:
    """Compile the per-device F/B(/W) op tables into per-device
    instruction streams (see :class:`InstrLowering`).

    The placement reuses the tick lowering's greedy in-order assignment
    (one-hop ring transfers, register-allocated stash/inbox slots), so
    an op's slot equals its start time in the unit-duration
    discrete-event replay; what changes is the executable: SENDs are
    explicit per-slot events, and slots with no SEND anywhere run
    communication-free.
    """
    ticks = lower_to_ticks(plan)
    N, V, nT = ticks.N, ticks.V, ticks.n_ticks
    NS = N * V
    has_w = ticks.has_w
    fsend = [False] * nT
    bsend = [False] * nT
    arsync = [False] * nT
    slot_of: dict = {}
    streams = []
    for n in range(N):
        instrs: list[Instr] = []
        for t in range(nT):
            k = ticks.kind[n][t]
            if ticks.fpark[n][t] >= 0:
                instrs.append(Instr(INSTR_RECV, t, ring="fwd",
                                    idx=ticks.fpark[n][t]))
            if ticks.bpark[n][t] >= 0:
                instrs.append(Instr(INSTR_RECV, t, ring="bwd",
                                    idx=ticks.bpark[n][t]))
            if k == TICK_IDLE:
                continue
            v = ticks.v[n][t]
            vs = v * N + n
            m = ticks.m[n][t]
            if k == TICK_AR:
                slot_of[("AR", m, vs)] = t
                instrs.append(Instr(INSTR_AR, t, kind=k, m=m, v=v))
                arsync[t] = True
                continue
            if k == TICK_F:
                slot_of[("F", m, vs)] = t
                if ticks.fsrc[n][t] == 1:
                    instrs.append(Instr(INSTR_RECV, t, ring="fwd"))
            elif k in (TICK_B, TICK_B_SEED):
                slot_of[("B", m, vs)] = t
                if k == TICK_B and ticks.bsrc[n][t] == 1:
                    instrs.append(Instr(INSTR_RECV, t, ring="bwd"))
            else:
                slot_of[("W", m, vs)] = t
            instrs.append(Instr(INSTR_RUN, t, kind=k, m=m, v=v))
            if k == TICK_F and vs < NS - 1:
                instrs.append(Instr(INSTR_SEND, t, ring="fwd"))
                fsend[t] = True
            elif k in (TICK_B, TICK_B_SEED) and vs > 0:
                instrs.append(Instr(INSTR_SEND, t, ring="bwd"))
                bsend[t] = True
            # register releases: the last reader frees its inputs
            if k == TICK_F and ticks.fsrc[n][t] == 2:
                instrs.append(Instr(INSTR_FREE, t, buf="f",
                                    idx=ticks.fr[n][t]))
            elif k == TICK_B and ticks.bsrc[n][t] == 2:
                instrs.append(Instr(INSTR_FREE, t, buf="b",
                                    idx=ticks.br[n][t]))
            if (k in (TICK_B, TICK_B_SEED) and not has_w) or k == TICK_W:
                instrs.append(Instr(INSTR_FREE, t, buf="x",
                                    idx=ticks.xr[n][t]))
            if k == TICK_W:
                instrs.append(Instr(INSTR_FREE, t, buf="c",
                                    idx=ticks.cr[n][t]))
        streams.append(tuple(instrs))
    return InstrLowering(ticks=ticks, streams=tuple(streams),
                         fsend=tuple(fsend), bsend=tuple(bsend),
                         slot_of=slot_of, arsync=tuple(arsync))
