"""Auto-planning: BaPipe's explorer drives the runtime configuration.

Closes the loop the paper describes in Fig. 3: profile the architecture,
explore (stage x tensor) factorisations of the mesh model axis and
micro-batch counts with the schedule cost models, and emit the runtime
``PipelineConfig`` + stage plan that the train/serve launchers consume
(``--auto-plan``).

A stage backed by T tensor-parallel chips is modelled as one BaPipe
"accelerator" with T x compute and T x HBM bandwidth but per-link ICI
bandwidth (tensor-parallel psums are accounted as an activation-size
communication term on top of the boundary transfer).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.explorer import explore, explore3d
from repro.core.hardware import (DeviceSpec, TPU_V5E, fused_device,
                                 heterogeneous_cluster, homogeneous_cluster,
                                 homogeneous_fleet)
from repro.core.profiler import profile_arch


@dataclasses.dataclass(frozen=True)
class AutoPlan:
    stages: int
    tensor: int
    n_microbatches: int
    schedule: str
    predicted_step_time: float
    predicted_speedup_over_dp: float
    virtual: int = 1                 # 1F1B-I interleave depth (V)
    mem_limit: int = 0               # zb-auto peak-live cap (0 = unbounded)
    data_axis: int = 1               # DP degree the prediction assumed
    # non-hidden gradient-sync time inside predicted_step_time: the
    # part of the data-axis all-reduce the drain bubble could NOT
    # absorb (0.0 when data_axis == 1 or fully hidden)
    predicted_sync_exposed: float = 0.0
    # per-stage chip widths (dp*tp) of a 3D plan; () = flat 1D plan.
    # Uniform widths are what ``apply`` maps onto the regular mesh; a
    # non-uniform vector is carried for reporting only (the analytic
    # ranking's winner — the runtime executes uniform plans)
    stage_widths: tuple = ()

    def apply(self, cfg: ArchConfig) -> ArchConfig:
        from repro.core.schedplan import canonical_name
        try:
            sched = canonical_name(self.schedule)
        except ValueError:
            # schedule may be None/unknown (e.g. a data-parallel
            # ExplorationResult carries no pipeline schedule)
            sched = "auto"
        return dataclasses.replace(cfg, stages=self.stages,
                                   tensor=self.tensor,
                                   virtual=self.virtual,
                                   schedule=sched,
                                   mem_limit=self.mem_limit
                                   if sched == "zb-auto" else 0)


def _stage_device(base: DeviceSpec, tensor: int) -> DeviceSpec:
    return fused_device(base, tensor)


def _valid_factorisations(cfg: ArchConfig, model_axis: int):
    t = 1
    while t <= model_axis:
        s = model_axis // t
        if model_axis % t == 0 and s <= cfg.n_layers:
            # tensor must divide the sharded dims (heads may replicate kv)
            heads_ok = cfg.n_heads % t == 0 or t == 1
            ssm_ok = cfg.ssm is None or t == 1
            ff_ok = (cfg.d_ff % t == 0) if cfg.d_ff else True
            if heads_ok and ssm_ok and ff_ok:
                yield s, t
        t *= 2


def auto_plan(cfg: ArchConfig, *, global_batch: int, seq_len: int,
              model_axis: int = 16, data_axis: int = 16,
              device: DeviceSpec = TPU_V5E,
              devices: Optional[Sequence[DeviceSpec]] = None,
              max_microbatches: Optional[int] = None,
              mem_limit: Optional[int] = None) -> AutoPlan:
    """Pick (stages, tensor, M, schedule) minimising the predicted
    mini-batch time subject to per-chip memory.  ``mem_limit`` caps the
    ZB-AUTO candidate's peak-live row (and is carried into the runtime
    config when that schedule wins).

    ``devices`` plans a *heterogeneous* pod: an explicit per-stage
    device list (paper §V's mixed-FPGA clusters) that fixes the stage
    count to ``len(devices)`` — only tensor sizes with
    ``s == len(devices)`` are searched, and the explorer ranks the
    candidates by the scheduled heterogeneous makespan of the
    per-device cost vector (uneven layer split + cost-shaped zb-auto
    tables).

    With ``data_axis > 1`` candidates are ranked by the *overlapped*
    makespan: compute plus only the exposed (non-bubble-hidden) part
    of the data-parallel gradient sync, per-stage buckets scheduled
    into the drain the way the AR-op runtime executes them
    (``predicted_sync_exposed`` reports that part)."""
    prof = profile_arch(cfg, seq=seq_len)
    # per-stage workload unit = tokens per data shard
    local_batch_tokens = max(1, global_batch // data_axis) * seq_len
    best: Optional[AutoPlan] = None
    for s, t in _valid_factorisations(cfg, model_axis):
        if devices is not None:
            if s != len(devices):
                continue
            cluster = heterogeneous_cluster(
                [_stage_device(d, t) for d in devices])
        else:
            cluster = homogeneous_cluster(_stage_device(device, t), s)
        b_loc = max(1, global_batch // data_axis)
        ms = [m for m in (1, 2, 4, 8, 16, 32) if m <= b_loc and b_loc % m == 0]
        if max_microbatches:
            ms = [m for m in ms if m <= max_microbatches] or ms[:1]
        r = explore(prof, cluster, local_batch_tokens,
                    candidate_Ms=[m for m in ms], consider_dp=False,
                    mem_limit=mem_limit, dp_degree=data_axis)
        if r.plan is None:
            continue
        cand = AutoPlan(stages=s, tensor=t, n_microbatches=max(1, r.M),
                        schedule=r.schedule or "1F1B-AS",
                        predicted_step_time=r.minibatch_time,
                        predicted_speedup_over_dp=r.speedup_over_dp,
                        virtual=r.V, mem_limit=mem_limit or 0,
                        data_axis=data_axis,
                        predicted_sync_exposed=(
                            r.grad_sync_eval.exposed
                            if r.grad_sync_eval else 0.0))
        if best is None or cand.predicted_step_time < best.predicted_step_time:
            best = cand
    if best is None:
        raise ValueError(f"no feasible (stage, tensor) factorisation for "
                         f"{cfg.arch_id} on model_axis={model_axis}")
    return best


def _tp_valid(cfg: ArchConfig, t: int) -> bool:
    """Can the architecture's sharded dims split ``t`` ways?"""
    if t == 1:
        return True
    heads_ok = cfg.n_heads % t == 0
    ssm_ok = cfg.ssm is None
    ff_ok = (cfg.d_ff % t == 0) if cfg.d_ff else True
    return heads_ok and ssm_ok and ff_ok


def auto_plan3d(cfg: ArchConfig, *, global_batch: int, seq_len: int,
                n_devices: int, device: DeviceSpec = TPU_V5E,
                mem_limit: Optional[int] = None) -> AutoPlan:
    """3D auto-planning: search per-stage (dp, tp) degrees over an
    ``n_devices`` homogeneous pool (:func:`repro.core.explorer.explore3d`)
    and emit the runtime config of the best EXECUTABLE — uniform
    (dp, tp) — candidate, which maps onto the regular ``(data, stage,
    tensor)`` mesh: ``stages = S``, ``tensor = tp``, ``data_axis = dp``.
    ``stage_widths`` carries the overall winner's per-stage chip
    widths; when the analytic best is non-uniform its predicted time
    still appears through ``predicted_speedup_over_dp``'s denominator
    being the executable candidate (the uniform plan is what ships).

    Unlike :func:`auto_plan` (which fixes the mesh split up front and
    explores inside it), the device budget is the only constraint here
    — the planner chooses how deep and how wide every stage is."""
    prof = profile_arch(cfg, seq=seq_len)
    gb = max(1, global_batch)
    batch_tokens = gb * seq_len
    # Ms the runtime can actually slice: divisors of the global batch
    # (the executable filter below additionally requires the per-replica
    # batch gb/dp to split into M microbatches)
    ms = [m for m in (1, 2, 4, 8, 16, 32) if m <= gb and gb % m == 0]
    res = explore3d(prof, homogeneous_fleet(device, n_devices),
                    batch_tokens, candidate_Ms=ms or None,
                    mem_limit=mem_limit)

    def _runnable(c) -> bool:
        dp = c.shards[0][0]
        return gb % dp == 0 and (gb // dp) % c.M == 0

    executable = [c for c in res.candidates
                  if c.uniform and c.n_stages <= cfg.n_layers
                  and _tp_valid(cfg, c.shards[0][1]) and _runnable(c)]
    if not executable:
        raise ValueError(
            f"no executable uniform 3D candidate for {cfg.arch_id} "
            f"on {n_devices} devices")
    win = executable[0]                 # candidates are ranked
    dp, tp = win.shards[0]
    return AutoPlan(
        stages=win.n_stages, tensor=tp, n_microbatches=win.M,
        schedule=win.schedule,
        predicted_step_time=win.predicted_time,
        predicted_speedup_over_dp=(
            res.incumbent.predicted_time / win.predicted_time
            if win.predicted_time else 0.0),
        mem_limit=mem_limit or 0, data_axis=dp,
        predicted_sync_exposed=win.sync_exposed,
        stage_widths=tuple(d * t for d, t in res.best.shards))


def _derated(base: DeviceSpec, factor: float) -> DeviceSpec:
    """``base`` slowed down by ``factor`` (>1 = slower): the drift
    monitor's per-stage slowdown becomes a cost-model derating of both
    compute and HBM streaming."""
    if factor <= 0:
        raise ValueError(f"slowdown factor must be positive, got {factor}")
    return dataclasses.replace(
        base,
        name=f"{base.name}~{factor:.2f}x",
        peak_flops=base.peak_flops / factor,
        hbm_bandwidth=base.hbm_bandwidth / factor)


def _same_config(a: AutoPlan, b: AutoPlan) -> bool:
    if (a.stages, a.tensor, a.n_microbatches, a.virtual) != \
            (b.stages, b.tensor, b.n_microbatches, b.virtual):
        return False
    from repro.core.schedplan import canonical_name
    try:
        return canonical_name(a.schedule) == canonical_name(b.schedule)
    except ValueError:
        return a.schedule == b.schedule


def replan(cfg: ArchConfig, incumbent: AutoPlan, *, budget_s: float,
           global_batch: int, seq_len: int,
           device: DeviceSpec = TPU_V5E,
           devices: Optional[Sequence[DeviceSpec]] = None,
           slowdown: Optional[Sequence[float]] = None,
           max_microbatches: Optional[int] = None,
           mem_limit: Optional[int] = None,
           clock=None) -> AutoPlan:
    """Deadline-bounded re-search around a running plan.

    Triggered by the drift monitor: the fleet the incumbent was planned
    for no longer matches reality, so re-run the (stages, tensor, M, V,
    schedule) exploration under the CURRENT cost model and return the
    winner — or the ``incumbent`` itself when the search runs out of
    ``budget_s`` seconds before evaluating anything, or when the best
    configuration found IS the incumbent's (identity-testable: callers
    compare ``replan(...) is plan`` to skip a no-op restart).

    The current cost model comes from either an explicit per-stage
    ``devices`` list or a ``slowdown`` vector (the drift monitor's
    measured/planned ratios, length ``incumbent.stages``), which derates
    the baseline ``device`` per stage.  Either pins the stage count to
    the incumbent's — live replanning moves micro-batching, layer cuts,
    virtual chunking, and the schedule; CHANGING the device count is the
    restart path (kill, :func:`repro.checkpoint.reshard.reshard_checkpoint`,
    relaunch).

    Never-worse guarantee: the incumbent's (stages, tensor)
    factorisation is evaluated FIRST (before any deadline check can
    exhaust the budget) with the incumbent's micro-batch count forced
    into the candidate set, and the explorer's schedule space contains
    the incumbent's schedule — so the returned plan's predicted step
    time under the new cost model is <= the incumbent config's.  The
    deadline is checked between candidates (search work is not
    preempted mid-candidate); ``budget_s <= 0`` returns the incumbent
    immediately.

    ``clock`` is injectable for tests (defaults to
    ``time.monotonic``)."""
    import time as _time
    clock = clock or _time.monotonic
    if budget_s <= 0:
        return incumbent
    if slowdown is not None:
        if devices is not None:
            raise ValueError("pass either devices or slowdown, not both")
        if len(slowdown) != incumbent.stages:
            raise ValueError(
                f"slowdown vector has {len(slowdown)} entries, incumbent "
                f"runs {incumbent.stages} stages")
        devices = [_derated(device, f) for f in slowdown]

    model_axis = incumbent.stages * incumbent.tensor
    data_axis = incumbent.data_axis
    prof = profile_arch(cfg, seq=seq_len)
    local_batch_tokens = max(1, global_batch // data_axis) * seq_len
    b_loc = max(1, global_batch // data_axis)

    facts = list(_valid_factorisations(cfg, model_axis))
    inc_key = (incumbent.stages, incumbent.tensor)
    facts.sort(key=lambda st: st != inc_key)   # incumbent's (s, t) first

    t0 = clock()
    best: Optional[AutoPlan] = None
    for i, (s, t) in enumerate(facts):
        if i > 0 and clock() - t0 >= budget_s:
            break
        if devices is not None:
            if s != len(devices):
                continue
            cluster = heterogeneous_cluster(
                [_stage_device(d, t) for d in devices])
        else:
            cluster = homogeneous_cluster(_stage_device(device, t), s)
        ms = [m for m in (1, 2, 4, 8, 16, 32)
              if m <= b_loc and b_loc % m == 0]
        if max_microbatches:
            ms = [m for m in ms if m <= max_microbatches] or ms[:1]
        if (incumbent.n_microbatches <= b_loc
                and b_loc % incumbent.n_microbatches == 0
                and incumbent.n_microbatches not in ms):
            ms.append(incumbent.n_microbatches)
        r = explore(prof, cluster, local_batch_tokens,
                    candidate_Ms=sorted(ms), consider_dp=False,
                    mem_limit=mem_limit, dp_degree=data_axis)
        if r.plan is None:
            continue
        cand = AutoPlan(stages=s, tensor=t, n_microbatches=max(1, r.M),
                        schedule=r.schedule or "1F1B-AS",
                        predicted_step_time=r.minibatch_time,
                        predicted_speedup_over_dp=r.speedup_over_dp,
                        virtual=r.V, mem_limit=mem_limit or 0,
                        data_axis=data_axis,
                        predicted_sync_exposed=(
                            r.grad_sync_eval.exposed
                            if r.grad_sync_eval else 0.0))
        if best is None or cand.predicted_step_time < best.predicted_step_time:
            best = cand
    if best is None:
        return incumbent
    if _same_config(best, incumbent):
        return incumbent
    return best
