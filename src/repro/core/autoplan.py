"""Auto-planning: BaPipe's explorer drives the runtime configuration.

Closes the loop the paper describes in Fig. 3: profile the architecture,
explore (stage x tensor) factorisations of the mesh model axis and
micro-batch counts with the schedule cost models, and emit the runtime
``PipelineConfig`` + stage plan that the train/serve launchers consume
(``--auto-plan``).

A stage backed by T tensor-parallel chips is modelled as one BaPipe
"accelerator" with T x compute and T x HBM bandwidth but per-link ICI
bandwidth (tensor-parallel psums are accounted as an activation-size
communication term on top of the boundary transfer).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.explorer import explore
from repro.core.hardware import (DeviceSpec, TPU_V5E, heterogeneous_cluster,
                                 homogeneous_cluster)
from repro.core.profiler import profile_arch


@dataclasses.dataclass(frozen=True)
class AutoPlan:
    stages: int
    tensor: int
    n_microbatches: int
    schedule: str
    predicted_step_time: float
    predicted_speedup_over_dp: float
    virtual: int = 1                 # 1F1B-I interleave depth (V)
    mem_limit: int = 0               # zb-auto peak-live cap (0 = unbounded)
    data_axis: int = 1               # DP degree the prediction assumed
    # non-hidden gradient-sync time inside predicted_step_time: the
    # part of the data-axis all-reduce the drain bubble could NOT
    # absorb (0.0 when data_axis == 1 or fully hidden)
    predicted_sync_exposed: float = 0.0

    def apply(self, cfg: ArchConfig) -> ArchConfig:
        from repro.core.schedplan import canonical_name
        try:
            sched = canonical_name(self.schedule)
        except ValueError:
            # schedule may be None/unknown (e.g. a data-parallel
            # ExplorationResult carries no pipeline schedule)
            sched = "auto"
        return dataclasses.replace(cfg, stages=self.stages,
                                   tensor=self.tensor,
                                   virtual=self.virtual,
                                   schedule=sched,
                                   mem_limit=self.mem_limit
                                   if sched == "zb-auto" else 0)


def _stage_device(base: DeviceSpec, tensor: int) -> DeviceSpec:
    return dataclasses.replace(
        base,
        name=f"{base.name}x{tensor}",
        peak_flops=base.peak_flops * tensor,
        hbm_bandwidth=base.hbm_bandwidth * tensor,
        memory_capacity=base.memory_capacity * tensor)


def _valid_factorisations(cfg: ArchConfig, model_axis: int):
    t = 1
    while t <= model_axis:
        s = model_axis // t
        if model_axis % t == 0 and s <= cfg.n_layers:
            # tensor must divide the sharded dims (heads may replicate kv)
            heads_ok = cfg.n_heads % t == 0 or t == 1
            ssm_ok = cfg.ssm is None or t == 1
            ff_ok = (cfg.d_ff % t == 0) if cfg.d_ff else True
            if heads_ok and ssm_ok and ff_ok:
                yield s, t
        t *= 2


def auto_plan(cfg: ArchConfig, *, global_batch: int, seq_len: int,
              model_axis: int = 16, data_axis: int = 16,
              device: DeviceSpec = TPU_V5E,
              devices: Optional[Sequence[DeviceSpec]] = None,
              max_microbatches: Optional[int] = None,
              mem_limit: Optional[int] = None) -> AutoPlan:
    """Pick (stages, tensor, M, schedule) minimising the predicted
    mini-batch time subject to per-chip memory.  ``mem_limit`` caps the
    ZB-AUTO candidate's peak-live row (and is carried into the runtime
    config when that schedule wins).

    ``devices`` plans a *heterogeneous* pod: an explicit per-stage
    device list (paper §V's mixed-FPGA clusters) that fixes the stage
    count to ``len(devices)`` — only tensor sizes with
    ``s == len(devices)`` are searched, and the explorer ranks the
    candidates by the scheduled heterogeneous makespan of the
    per-device cost vector (uneven layer split + cost-shaped zb-auto
    tables).

    With ``data_axis > 1`` candidates are ranked by the *overlapped*
    makespan: compute plus only the exposed (non-bubble-hidden) part
    of the data-parallel gradient sync, per-stage buckets scheduled
    into the drain the way the AR-op runtime executes them
    (``predicted_sync_exposed`` reports that part)."""
    prof = profile_arch(cfg, seq=seq_len)
    # per-stage workload unit = tokens per data shard
    local_batch_tokens = max(1, global_batch // data_axis) * seq_len
    best: Optional[AutoPlan] = None
    for s, t in _valid_factorisations(cfg, model_axis):
        if devices is not None:
            if s != len(devices):
                continue
            cluster = heterogeneous_cluster(
                [_stage_device(d, t) for d in devices])
        else:
            cluster = homogeneous_cluster(_stage_device(device, t), s)
        b_loc = max(1, global_batch // data_axis)
        ms = [m for m in (1, 2, 4, 8, 16, 32) if m <= b_loc and b_loc % m == 0]
        if max_microbatches:
            ms = [m for m in ms if m <= max_microbatches] or ms[:1]
        r = explore(prof, cluster, local_batch_tokens,
                    candidate_Ms=[m for m in ms], consider_dp=False,
                    mem_limit=mem_limit, dp_degree=data_axis)
        if r.plan is None:
            continue
        cand = AutoPlan(stages=s, tensor=t, n_microbatches=max(1, r.M),
                        schedule=r.schedule or "1F1B-AS",
                        predicted_step_time=r.minibatch_time,
                        predicted_speedup_over_dp=r.speedup_over_dp,
                        virtual=r.V, mem_limit=mem_limit or 0,
                        data_axis=data_axis,
                        predicted_sync_exposed=(
                            r.grad_sync_eval.exposed
                            if r.grad_sync_eval else 0.0))
        if best is None or cand.predicted_step_time < best.predicted_step_time:
            best = cand
    if best is None:
        raise ValueError(f"no feasible (stage, tensor) factorisation for "
                         f"{cfg.arch_id} on model_axis={model_axis}")
    return best
