"""Hardware descriptions for BaPipe's explorer.

BaPipe consumes per-accelerator *hardware constraints*: compute power,
memory bandwidth, memory capacity, and link (communication) bandwidth
(paper Fig. 3).  Clusters may be heterogeneous — every accelerator in the
daisy chain can be a different device.

Units: FLOP/s, bytes/s, bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

GiB = 1024 ** 3
GB = 1e9
TFLOPS = 1e12
GBps = 1e9


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One accelerator."""

    name: str
    peak_flops: float          # dense matmul peak (training precision)
    hbm_bandwidth: float       # high-bandwidth (device) memory, bytes/s
    memory_capacity: float     # high-bandwidth memory capacity, bytes
    link_bandwidth: float      # p2p link to the pipeline neighbour, bytes/s
    # FPGA-ish knob: can this device compute FP and BP concurrently
    # (spatial dataflow) and stream outputs while computing?
    async_capable: bool = False
    # Fraction of peak actually achievable on DNN layers (efficiency).
    efficiency: float = 0.5
    # Second-tier memory for weight spill (FPGA DDR).  0 => hard limit.
    spill_bandwidth: float = 0.0
    # Per-mesh-axis link bandwidths, bytes/s (None = inherit the scalar
    # ``link_bandwidth``).  The three axes carry different traffic:
    # ``stage`` the pipeline boundary activations/errors, ``data`` the
    # gradient all-reduce buckets, ``tensor`` the per-layer collective
    # ops.  On real topologies they are different links (e.g. intra-host
    # ICI/NVLink for tensor, inter-host DCN for data), so the explorer's
    # AR and TP-collective costs must not read the stage link.  An
    # EXPLICIT zero is rejected at construction: the old ``0.0`` default
    # silently fell back to ``link_bandwidth``, which let 3D cost models
    # quietly price TP collectives at the inter-host rate.
    data_bandwidth: Optional[float] = None
    stage_bandwidth: Optional[float] = None
    tensor_bandwidth: Optional[float] = None

    def __post_init__(self):
        for axis in ("data", "stage", "tensor"):
            bw = getattr(self, f"{axis}_bandwidth")
            if bw is not None and bw <= 0.0:
                raise ValueError(
                    f"{self.name}: {axis}_bandwidth must be positive "
                    f"(got {bw!r}); pass None to inherit link_bandwidth")
        if self.link_bandwidth <= 0.0:
            raise ValueError(f"{self.name}: link_bandwidth must be "
                             f"positive (got {self.link_bandwidth!r})")

    @property
    def effective_flops(self) -> float:
        return self.peak_flops * self.efficiency

    def axis_bandwidth(self, axis: str) -> float:
        """Link bandwidth of one mesh axis (``data``/``stage``/
        ``tensor``).  The fallback to the scalar ``link_bandwidth`` is
        explicit: only an UNSET (None) per-axis entry inherits it; a
        zero entry is a construction error, never a silent fallback."""
        try:
            bw = getattr(self, f"{axis}_bandwidth")
        except AttributeError:
            raise ValueError(f"unknown mesh axis {axis!r}") from None
        return self.link_bandwidth if bw is None else bw


# ---------------------------------------------------------------------------
# Catalogue: the paper's devices + our TPU target.
# ---------------------------------------------------------------------------

# TPU v5e — the target of this reproduction (per-chip).
TPU_V5E = DeviceSpec(
    name="tpu_v5e",
    peak_flops=197e12,          # bf16
    hbm_bandwidth=819 * GBps,
    memory_capacity=16 * GiB,
    link_bandwidth=50 * GBps,   # per ICI link
    async_capable=True,         # XLA async collectives overlap with compute
    efficiency=0.55,
    # stage/tensor neighbours sit on the intra-pod ICI torus; the data
    # (DP replica) axis typically crosses pods over DCN at half the rate
    data_bandwidth=25 * GBps,
    stage_bandwidth=50 * GBps,
    tensor_bandwidth=50 * GBps,
)

# NVIDIA V100 16GB (paper's GPU cluster), PCIe Gen3 x16 interconnect.
V100 = DeviceSpec(
    name="v100",
    peak_flops=125e12,          # tensor-core fp16
    hbm_bandwidth=900 * GBps,
    memory_capacity=16 * GiB,
    link_bandwidth=13 * GBps,   # PCIe gen3 x16 effective
    async_capable=False,        # paper: GPUs compute/communicate synchronously
    efficiency=0.35,
    # DP replicas of a V100 cluster talk across hosts (paper's setup):
    # the gradient buckets ride the NIC, not the intra-host PCIe switch
    data_bandwidth=12.5 * GBps,
    stage_bandwidth=13 * GBps,
    tensor_bandwidth=13 * GBps,
)

def _fpga(name: str, dsp: int, onchip_mb: float, ddr_gbps: float,
          transceiver_gbps: float) -> DeviceSpec:
    # Paper Table 5.  DSP slice @ ~500 MHz, 2 MACs/cycle (fp16 packed).
    peak = dsp * 500e6 * 2 * 2      # 2 ops per MAC
    # On-chip BRAM/URAM aggregate bandwidth: thousands of 72-bit ports at
    # 500 MHz — effectively tens of TB/s; weights resident on-chip stream
    # for free (BaPipe's §4.3 premise).  DDR (40 GB/s) is the *DP* tier.
    onchip_bw = (onchip_mb * 1e6 / 8) / 36e3 * 500e6    # ~0.6 TB/s per MB
    return DeviceSpec(
        name=name,
        peak_flops=peak,
        hbm_bandwidth=onchip_bw,
        memory_capacity=onchip_mb * 1e6 / 8,              # Mb -> bytes
        link_bandwidth=transceiver_gbps * GBps,
        async_capable=True,          # FPGA: streaming dataflow (paper §3.2)
        efficiency=0.8,
        spill_bandwidth=ddr_gbps * GBps,   # weights beyond on-chip -> DDR
    )

VCU118 = _fpga("vcu118", dsp=6840, onchip_mb=345.9, ddr_gbps=40,
               transceiver_gbps=25)
VCU129 = _fpga("vcu129", dsp=12288, onchip_mb=454.9, ddr_gbps=40,
               transceiver_gbps=25)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A 1-D daisy chain of (possibly heterogeneous) accelerators."""

    devices: tuple[DeviceSpec, ...]

    @property
    def n(self) -> int:
        return len(self.devices)

    @property
    def homogeneous(self) -> bool:
        return len({d.name for d in self.devices}) == 1

    def link_bandwidth(self, i: int) -> float:
        """Bandwidth of the link between stage i and stage i+1 (min of ends)."""
        return min(self.devices[i].link_bandwidth,
                   self.devices[i + 1].link_bandwidth)

    def axis_bandwidth(self, axis: str) -> float:
        """Cluster-wide bandwidth of one mesh axis: the slowest
        device's entry bounds the collective (ring all-reduce moves at
        the slowest link)."""
        return min(d.axis_bandwidth(axis) for d in self.devices)


def homogeneous_cluster(dev: DeviceSpec, n: int) -> ClusterSpec:
    return ClusterSpec(devices=(dev,) * n)


def heterogeneous_cluster(devs: Sequence[DeviceSpec]) -> ClusterSpec:
    return ClusterSpec(devices=tuple(devs))


# ---------------------------------------------------------------------------
# Device pools: the 3D explorer's hardware input.
# ---------------------------------------------------------------------------

def fused_device(base: DeviceSpec, width: int) -> DeviceSpec:
    """Model a ``width``-chip tensor-parallel stage group as one BaPipe
    accelerator: width x compute, HBM bandwidth and capacity, while the
    per-axis link bandwidths stay per-chip (collectives move at the
    link rate regardless of the group size)."""
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    if width == 1:
        return base
    return dataclasses.replace(
        base,
        name=f"{base.name}x{width}",
        peak_flops=base.peak_flops * width,
        hbm_bandwidth=base.hbm_bandwidth * width,
        memory_capacity=base.memory_capacity * width)


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """An UNORDERED pool of accelerators plus per-axis fabric rates —
    what the 3D explorer plans against.  Unlike :class:`ClusterSpec`
    (an ordered daisy chain with one device per stage), a fleet is raw
    capacity: the planner decides how many chips each stage gets (its
    ``dp x tp`` shard) and only then derives the chain, so "fat stages
    buy width instead of depth" is expressible.

    Devices within one stage group must be identical (a TP group lock-
    steps its chips); the pool itself may mix device types — groups are
    carved from the pool in order."""

    devices: tuple[DeviceSpec, ...]

    def __post_init__(self):
        if not self.devices:
            raise ValueError("FleetSpec needs at least one device")

    @property
    def n_devices(self) -> int:
        return len(self.devices)

    @property
    def homogeneous(self) -> bool:
        return len({d.name for d in self.devices}) == 1

    @property
    def base(self) -> DeviceSpec:
        return self.devices[0]

    def chain(self, widths: Sequence[int]) -> ClusterSpec:
        """Carve the pool, in order, into ``len(widths)`` stage groups
        of ``widths[i]`` chips each and return the derived daisy chain
        of fused stage accelerators.  Rejects over-budget carvings and
        mixed-device groups."""
        widths = [int(w) for w in widths]
        if any(w < 1 for w in widths):
            raise ValueError(f"stage widths must be >= 1, got {widths}")
        if sum(widths) > self.n_devices:
            raise ValueError(
                f"stage widths {widths} need {sum(widths)} devices, "
                f"fleet has {self.n_devices}")
        stages, k = [], 0
        for w in widths:
            group = self.devices[k:k + w]
            k += w
            if len({d.name for d in group}) != 1:
                raise ValueError(
                    f"stage group {group} mixes device types; a TP "
                    f"group's chips must be identical")
            stages.append(fused_device(group[0], w))
        return ClusterSpec(devices=tuple(stages))


def homogeneous_fleet(dev: DeviceSpec, n: int) -> FleetSpec:
    return FleetSpec(devices=(dev,) * n)
