"""Continuous-batching request scheduler over the slot-based KV cache.

Serving real traffic means requests arrive at different times, have
different prompt lengths, and finish at different times — yet the
pipeline wants one fixed-shape compiled step.  The reconciliation is the
slot abstraction: the decode cache's batch rows are *slots*, each with
its own per-layer ``len`` offset (``model.init_cache`` keeps them as
``[L, B]`` vectors), so requests at different sequence positions coexist
in one batch row-set.  Every engine step processes ``chunk`` columns for
every slot; a per-slot ``n_valid`` count (0 = idle, 1 = decode tick,
up to ``chunk`` = chunked prefill, Sarathi-style) says how many columns
are real.  Roles are pure data — admitting, retiring, or switching a
slot from prefill to decode never recompiles.

The scheduler here is the host-side half: it admits arrivals into free
slots, chunks their prompts, feeds decode ticks of running requests, and
emits the mixed per-step op tables through :func:`schedplan.build_schedule`
(micro-batch ``m`` of the ring table carries slots ``[m*mb, (m+1)*mb)``,
so a table op is a mixed bundle of prefill chunks and decode ticks).
``ContinuousEngine`` closes the loop against any compiled serve step —
the pipelined ``runtime.make_serve_step`` or the single-device
:func:`make_local_serve_step` reference.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig

IDLE, PREFILL, DECODE = "idle", "prefill", "decode"


# ---------------------------------------------------------------------------
# Requests and per-step work descriptions.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One generation request: prompt tokens in, ``max_new`` tokens out."""
    rid: int
    prompt: list[int]
    max_new: int
    arrival: int = 0          # engine step at which the request arrives

    # runtime state (managed by the scheduler)
    slot: int = -1
    pos: int = 0              # prompt tokens already prefilled into the cache
    generated: list[int] = dataclasses.field(default_factory=list)
    t_admit: int = -1
    t_first: int = -1         # step that produced the first output token
    t_done: int = -1

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


@dataclasses.dataclass(frozen=True)
class SlotWork:
    """What one cache slot does during one engine step."""
    slot: int
    kind: str                 # idle | prefill | decode
    n_valid: int
    rid: int = -1


@dataclasses.dataclass
class StepPlan:
    """Device-ready inputs for one engine step (static shapes)."""
    tokens: np.ndarray        # [n_slots, chunk] int32
    n_valid: np.ndarray       # [n_slots] int32
    work: list[SlotWork]

    @property
    def busy(self) -> int:
        return int(np.sum(self.n_valid > 0))


# ---------------------------------------------------------------------------
# Scheduler: admission + per-step role assignment.
# ---------------------------------------------------------------------------

class ServeScheduler:
    """Greedy continuous-batching scheduler.

    Admission policy: first-free-slot, FIFO over arrivals.  A slot runs
    its request's chunked prefill to completion (one ``chunk``-column
    bite per step), then decodes one token per step until ``max_new``
    tokens exist, then frees.  Prefill chunks and decode ticks of
    different slots ride the same step — that is the continuous-batching
    win: a new request's prefill never stalls running decodes, it fills
    the idle columns of the same compiled table.
    """

    def __init__(self, n_slots: int, chunk: int):
        assert n_slots >= 1 and chunk >= 1
        self.n_slots = n_slots
        self.chunk = chunk
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.retired: list[Request] = []

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def active(self) -> bool:
        return any(r is not None for r in self.slots)

    def admit(self, req: Request, t: int = 0) -> bool:
        """Place ``req`` into the lowest free slot; False when full."""
        free = self.free_slots()
        if not free:
            return False
        req.slot = free[0]
        req.pos = 0
        req.t_admit = t
        self.slots[req.slot] = req
        return True

    def plan_step(self) -> StepPlan:
        """Assign this step's per-slot roles and build the device inputs."""
        C = self.chunk
        tokens = np.zeros((self.n_slots, C), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        work: list[SlotWork] = []
        for s, req in enumerate(self.slots):
            if req is None:
                work.append(SlotWork(s, IDLE, 0))
                continue
            if req.pos < len(req.prompt):
                nv = min(C, len(req.prompt) - req.pos)
                tokens[s, :nv] = req.prompt[req.pos:req.pos + nv]
                n_valid[s] = nv
                work.append(SlotWork(s, PREFILL, nv, req.rid))
            else:
                tokens[s, 0] = req.generated[-1]
                n_valid[s] = 1
                work.append(SlotWork(s, DECODE, 1, req.rid))
        return StepPlan(tokens=tokens, n_valid=n_valid, work=work)

    def observe(self, sp: StepPlan, next_tokens: np.ndarray, t: int = 0
                ) -> list[Request]:
        """Fold one step's sampled tokens back into the request states.
        Returns the requests retired by this step (their slots are free
        for the next admission round)."""
        finished: list[Request] = []
        for w in sp.work:
            req = self.slots[w.slot]
            if w.kind == IDLE:
                continue
            assert req is not None and req.rid == w.rid
            tok = int(next_tokens[w.slot])
            if w.kind == PREFILL:
                req.pos += w.n_valid
                if req.pos < len(req.prompt):
                    continue          # mid-prompt chunk: logits discarded
            # prompt just completed (its last logit IS the first new
            # token) or a decode tick: either way ``tok`` is output.
            req.generated.append(tok)
            if req.t_first < 0:
                req.t_first = t
            if req.done:
                req.t_done = t
                req.slot = -1
                self.slots[w.slot] = None
                self.retired.append(req)
                finished.append(req)
        return finished


# ---------------------------------------------------------------------------
# Mixed prefill/decode op tables (schedplan IR view of one engine step).
# ---------------------------------------------------------------------------

def mixed_op_table(work: Sequence[SlotWork], M: int, N: int, V: int = 1,
                   schedule: str = "auto"):
    """Lower one engine step to the schedplan IR: the ring schedule's
    op table from :func:`build_schedule` plus the per-micro-batch slot
    roles it carries (micro-batch ``m`` = slots ``[m*mb, (m+1)*mb)``).

    Returns ``(plan, roles)`` where ``roles[m]`` is the tuple of slot
    kinds bundled into micro-batch ``m`` — the table's F op for ``m`` is
    a *mixed* prefill/decode bundle exactly when the tuple mixes kinds.
    """
    from repro.core import schedplan as SP
    name = SP.resolve_ring_schedule(schedule, V)
    plan = SP.build_schedule(name, M, N, V)
    n_slots = len(work)
    assert n_slots % M == 0, (n_slots, M)
    mb = n_slots // M
    roles = {m: tuple(w.kind for w in work[m * mb:(m + 1) * mb])
             for m in range(M)}
    return plan, roles


def format_mixed_table(plan, roles) -> str:
    """Human-readable mixed table: one line per device, ops annotated
    with the role letters (P/D/-) of the slots their micro-batch holds."""
    tag = {PREFILL: "P", DECODE: "D", IDLE: "-"}
    lines = []
    for dev, ops in enumerate(plan.device_ops):
        cells = []
        for op in ops:
            if op.kind != "F":
                continue
            r = "".join(tag[k] for k in roles[op.m])
            cells.append(f"F{op.m}" + (f".{op.v}" if plan.V > 1 else "")
                         + f"[{r}]")
        lines.append(f"dev{dev}: " + " ".join(cells))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Explorer-style memory gating: how many slots fit a device?
# ---------------------------------------------------------------------------

def kv_bytes_per_slot(cfg: ArchConfig, max_len: int, itemsize: int = 4
                      ) -> int:
    """Cache bytes one slot pins across ALL layers (model total)."""
    if cfg.attn_kind == "mla":
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
    else:
        per_tok = 2 * max(1, cfg.n_kv_heads) * cfg.resolved_head_dim
    return cfg.n_layers * max_len * per_tok * itemsize


def serve_slot_budget(cfg: ArchConfig, max_len: int, mem_limit_bytes: float,
                      *, n_stages: int = 0, weight_bytes: float = 0.0,
                      itemsize: int = 4, microbatches: int = 1) -> int:
    """Largest slot count whose per-stage cache footprint (plus resident
    stage weights) fits under ``mem_limit_bytes`` — the serving analogue
    of the explorer's activation-memory gate (``partition.stage_memory``):
    where training trades micro-batch depth for live activations, serving
    trades concurrent requests for pinned KV rows.  The result is floored
    to a multiple of ``microbatches`` (the ring splits slots evenly)."""
    stages = max(1, n_stages or cfg.stages)
    layers_per_stage = math.ceil(cfg.n_layers / stages)
    per_slot = kv_bytes_per_slot(cfg, max_len, itemsize) \
        * layers_per_stage / cfg.n_layers
    free = mem_limit_bytes - weight_bytes
    if free < per_slot:
        return 0
    slots = int(free // per_slot)
    return (slots // microbatches) * microbatches


# ---------------------------------------------------------------------------
# The engine: open-loop driver over any compiled serve step.
# ---------------------------------------------------------------------------

_reset_jit = None


def reset_slot_offsets(cache, mask):
    """Zero the per-slot kv ``len`` offsets where ``mask`` is True (slot
    admission / reuse).  Jitted once at module scope so every engine in a
    process shares the compiled reset instead of retracing per engine."""
    global _reset_jit
    if _reset_jit is None:
        import jax
        import jax.numpy as jnp
        from repro.pipeline.runtime import _is_kv_len

        def do(c, m):
            return jax.tree_util.tree_map_with_path(
                lambda p, l: jnp.where(m, 0, l) if _is_kv_len(p) else l, c)

        _reset_jit = jax.jit(do, donate_argnums=(0,))
    return _reset_jit(cache, mask)


class ContinuousEngine:
    """Drives admission -> step -> observe over a compiled serve step.

    ``step(params, cache, dict(tokens, n_valid)) -> (logits, cache)`` with
    ``logits`` ``[n_slots, 1, vocab]`` gathered at each slot's last valid
    column.  Sampling is greedy argmax (bit-stable across runs, which is
    what the invariance tests pin).  The clock is the engine-step counter:
    arrivals are admitted when their ``arrival`` step has passed and a
    slot is free.
    """

    def __init__(self, cfg: ArchConfig, step: Callable, params, cache,
                 n_slots: int, chunk: int):
        if cfg.family in ("ssm", "hybrid", "audio"):
            raise ValueError(
                f"continuous batching is attention-family only (gqa/mla); "
                f"{cfg.family} carries recurrent state that padded slot "
                f"columns would pollute")
        self.cfg = cfg
        self.step = step
        self.params = params
        self.cache = cache
        self.sched = ServeScheduler(n_slots, chunk)
        self.steps_run = 0
        self.step_log: list[StepPlan] = []

    def _reset_slots(self, slot_ids: list[int]):
        """Rewind freed/reused slots' kv offsets to 0.  Stale K/V rows are
        harmless: positions below the new request's write head get
        overwritten, positions above it stay causally masked."""
        mask = np.zeros((self.sched.n_slots,), bool)
        mask[slot_ids] = True
        self.cache = reset_slot_offsets(self.cache, mask)

    def run(self, requests: Sequence[Request], max_steps: int = 10_000
            ) -> list[Request]:
        """Open loop: admit each request at its ``arrival`` step, run
        until every request retired.  Returns the requests retired by
        THIS call (the engine keeps the full history in
        ``sched.retired``)."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        n0 = len(self.sched.retired)
        t = self.steps_run
        while pending or self.sched.active():
            admitted = []
            while pending and pending[0].arrival <= t:
                if not self.sched.admit(pending[0], t):
                    break
                admitted.append(pending.pop(0).slot)
            if admitted:
                self._reset_slots(admitted)
            sp = self.sched.plan_step()
            if sp.busy == 0:
                # nothing in flight: jump the clock to the next arrival
                t = max(t + 1, pending[0].arrival)
                continue
            logits, self.cache = self.step(
                self.params, self.cache,
                dict(tokens=np.asarray(sp.tokens),
                     n_valid=np.asarray(sp.n_valid)))
            toks = np.asarray(logits[:, 0, :self.cfg.vocab].argmax(axis=-1))
            self.sched.observe(sp, toks, t)
            self.step_log.append(sp)
            self.steps_run += 1
            t += 1
            if self.steps_run > max_steps:
                raise RuntimeError("engine did not drain "
                                   f"within {max_steps} steps")
        return self.sched.retired[n0:]


# ---------------------------------------------------------------------------
# Single-device reference step (tests + bench baselines).
# ---------------------------------------------------------------------------

def make_local_serve_step(cfg: ArchConfig):
    """Single-device serve step with the same contract as the pipelined
    ``runtime.make_serve_step``: mixed per-slot prefill/decode over the
    per-slot-offset cache, logits gathered at each slot's last valid
    column, offsets advanced by ``n_valid``."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as M
    from repro.pipeline.runtime import _advance_len, _restore_len

    @jax.jit
    def step(params, cache, batch):
        nv = batch["n_valid"].astype(jnp.int32)
        x, _, new_cache = M.forward(cfg, params,
                                    dict(tokens=batch["tokens"]),
                                    cache=cache)
        # forward advanced every row by the full chunk width; rewind and
        # re-advance by each slot's true valid count
        new_cache = _restore_len(new_cache, cache)
        new_cache = _advance_len(new_cache, nv)
        col = jnp.clip(nv, 1, x.shape[1]) - 1
        h = jnp.take_along_axis(x, col[:, None, None], axis=1)
        table = params.get("head", params["embed"])
        logits = (h @ table.T).astype(jnp.float32)
        return logits, new_cache

    return step
