"""Discrete-event simulator for intra-batch pipeline schedules.

Re-derives the paper's Table 1/2 numbers tick-by-tick instead of trusting
the closed forms: every FP/BP of every micro-batch on every stage is a task;
stage-boundary transfers are tasks too.  Three communication models:

* ``free``     — transfers are instantaneous (paper's async figures omit SR:
                 "complete overlap by asynchronous execution").
* ``latency``  — transfers take SR on a dedicated comm engine, overlapping
                 compute (1F1B-SO's doubled warm-up makes this hideable).
* ``blocking`` — a transfer occupies *both* end-point devices for SR
                 (1F1B-SNO: synchronous execution, no overlap).

The simulator also tracks the peak number of live micro-batch activations
per stage, which is the paper's "features memory" column.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Sequence


@dataclasses.dataclass
class SimResult:
    makespan: float
    peak_live: list[int]          # per stage: peak resident activations
    idle: list[float]             # per stage: total idle (bubble) time

    def bubble_fraction(self, stage: int = 0) -> float:
        return self.idle[stage] / self.makespan if self.makespan else 0.0


def _order_1f1b(M: int, N: int, n: int, warmup: int) -> list[tuple[str, int]]:
    """Per-stage op order: ('F'|'B', microbatch)."""
    warmup = max(1, min(M, warmup))
    ops: list[tuple[str, int]] = [("F", m) for m in range(warmup)]
    nf, nb = warmup, 0
    while nb < M:
        ops.append(("B", nb)); nb += 1
        if nf < M:
            ops.append(("F", nf)); nf += 1
    return ops


def simulate(schedule: str, M: int, N: int,
             F: float | Sequence[float], B: float | Sequence[float],
             SR: float = 0.0) -> SimResult:
    """Simulate one mini-batch of M micro-batches through N stages."""
    Fs = list(F) if not isinstance(F, (int, float)) else [float(F)] * N
    Bs = list(B) if not isinstance(B, (int, float)) else [float(B)] * N
    assert len(Fs) == len(Bs) == N

    if schedule == "1F1B-AS":
        comm = "free"
        orders = [_order_1f1b(M, N, n, N - n) for n in range(N)]
    elif schedule == "FBP-AS":
        # FPGA spatial dataflow: FP and BP *timeshare* the DSP array, so a
        # (F, B) pair still costs F+B of device time (paper Table 1 keeps
        # the makespan equal to 1F1B-AS); what changes is the pipeline
        # depth of BP behind FP — doubled warm-up — hence 2x live
        # activations and the gentler 2a/(F+B) bandwidth demand.
        comm = "free"
        orders = [_order_1f1b(M, N, n, 2 * (N - n) - 1) for n in range(N)]
    elif schedule == "1F1B-SNO":
        comm = "blocking"
        orders = [_order_1f1b(M, N, n, N - n) for n in range(N)]
    elif schedule == "1F1B-SO":
        comm = "latency"
        orders = [_order_1f1b(M, N, n, 2 * (N - n) - 1) for n in range(N)]
    else:
        raise ValueError(schedule)

    # --- task state ------------------------------------------------------
    f_done = [[-1.0] * N for _ in range(M)]    # completion time of F[m][n]
    b_done = [[-1.0] * N for _ in range(M)]
    f_ready = [[-1.0] * N for _ in range(M)]   # input-activation arrival
    b_ready = [[-1.0] * N for _ in range(M)]   # error arrival
    for m in range(M):
        f_ready[m][0] = 0.0                    # stage 0 reads local data
    dev_free = [0.0] * N
    busy = [0.0] * N                           # accumulated busy time
    ptr = [0] * N                              # next op index
    n_done = 0
    total_ops = 2 * M * N

    def deliver(kind: str, m: int, n_from: int, t_prod: float):
        """Schedule the transfer of an activation/error to the neighbour."""
        if kind == "F":
            if n_from == N - 1:
                b_ready[m][N - 1] = t_prod     # loss: error available locally
                return None
            tgt = (m, n_from + 1, "F")
        else:
            if n_from == 0:
                return None
            tgt = (m, n_from - 1, "B")
        return tgt

    pending_xfer: list[tuple[float, int, str, int, int]] = []  # (ready, m, kind, src, dst)

    def try_transfers(now_unused=None):
        """Fire every transfer whose constraints are satisfiable; returns
        earliest next-possible start among the rest."""
        nonlocal pending_xfer
        fired = True
        while fired:
            fired = False
            rest = []
            for (rdy, m, kind, src, dst) in sorted(pending_xfer):
                if comm == "free":
                    (f_ready if kind == "F" else b_ready)[m][dst] = rdy
                    fired = True
                elif comm == "latency":
                    (f_ready if kind == "F" else b_ready)[m][dst] = rdy + SR
                    fired = True
                else:                           # blocking: both devices busy SR
                    start = max(rdy, dev_free[src], dev_free[dst])
                    # only fire if neither device has a *startable* compute
                    # strictly earlier (keeps devices from starving xfers
                    # while staying work-conserving)
                    dev_free[src] = start + SR
                    dev_free[dst] = start + SR
                    busy[src] += SR
                    busy[dst] += SR
                    (f_ready if kind == "F" else b_ready)[m][dst] = start + SR
                    fired = True
            pending_xfer = rest

    # --- main loop: repeatedly start the globally-earliest runnable op ----
    while n_done < total_ops:
        try_transfers()
        best = None                            # (start, n, kind, m)
        for n in range(N):
            if ptr[n] >= len(orders[n]):
                continue
            kind, m = orders[n][ptr[n]]
            if kind == "F" and f_ready[m][n] >= 0:
                s = max(dev_free[n], f_ready[m][n])
            elif kind == "B" and b_ready[m][n] >= 0 and f_done[m][n] >= 0:
                s = max(dev_free[n], b_ready[m][n], f_done[m][n])
            else:
                continue
            if best is None or s < best[0]:
                best = (s, n, kind, m)
        assert best is not None, "pipeline deadlock (bad op order)"
        s, n, kind, m = best
        dur = Fs[n] if kind == "F" else Bs[n]
        end = s + dur
        dev_free[n] = end
        busy[n] += dur
        if kind == "F":
            f_done[m][n] = end
        else:
            b_done[m][n] = end
        ptr[n] += 1
        tgt = deliver(kind, m, n, end)
        if tgt is not None:
            tm, tn, tkind = tgt
            pending_xfer.append((end, tm, tkind, n, tn))
        n_done += 1

    try_transfers()
    makespan = max(max(r) for r in b_done)

    # peak live activations per stage: F done (or started) but B not done.
    peak = []
    for n in range(N):
        events = ([(f_done[m][n] - (Fs[n]), +1) for m in range(M)]
                  + [(b_done[m][n], -1) for m in range(M)])
        events.sort()
        live = pk = 0
        for _, delta in events:
            live += delta
            pk = max(pk, live)
        peak.append(pk)
    idle = [makespan - busy[n] for n in range(N)]
    return SimResult(makespan=makespan, peak_live=peak, idle=idle)
