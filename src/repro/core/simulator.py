"""Discrete-event simulator for intra-batch pipeline schedules.

Re-derives the paper's Table 1/2 numbers tick-by-tick instead of trusting
the closed forms: every FP/BP of every micro-batch on every stage is a task;
stage-boundary transfers are tasks too.  Three communication models:

* ``free``     — transfers are instantaneous (paper's async figures omit SR:
                 "complete overlap by asynchronous execution").
* ``latency``  — transfers take SR on a dedicated comm engine, overlapping
                 compute (1F1B-SO's doubled warm-up makes this hideable).
* ``blocking`` — a transfer occupies *both* end-point devices for SR
                 (1F1B-SNO: synchronous execution, no overlap).

Op orders come from the schedule-plan IR (:mod:`repro.core.schedplan`):
``simulate`` builds the per-device op table once and replays it, so the
simulator, the closed forms and the SPMD runtime all execute the same
compiled order.  Interleaved 1F1B (``1F1B-I``) runs V *virtual stages*
per device (virtual stage ``v*N + n`` is chunk v of device n) in
streaming chunk-pass order — the runtime's circular ``ppermute``
schedule, closed-form makespan ``(M*V + N - 1)(F + B)/V`` for M >= N.
``1F1B-I-ML`` replays the Megatron memory-lean interleaved order (groups
of N micro-batches, warm-up ``2(N-n-1) + (V-1)N``): same makespan,
``(V-1)N`` resident-features term instead of ``(V-1)M``.  ``dapple``
replays DAPPLE's early-backward order (== synchronous 1F1B), and
``zb-h1`` replays the zero-bubble split-backward table: its ``B`` ops
(input gradient, B/2 each) propagate errors upstream while ``W`` ops
(weight gradient, B/2, no transfer) fill the drain bubbles — makespan
``M(F+B) + (N-1)(F + B/2)``.

The simulator also tracks the peak number of live micro-batch activations
per device, which is the paper's "features memory" column.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import schedplan as SP


@dataclasses.dataclass
class SimResult:
    makespan: float
    peak_live: list[int]          # per device: peak resident activations
    idle: list[float]             # per device: total idle (bubble) time

    def bubble_fraction(self, stage: int = 0) -> float:
        return self.idle[stage] / self.makespan if self.makespan else 0.0


# default communication model per schedule-table name (the paper's async
# figures omit SR; SNO pays it blocking, SO hides it behind compute)
_DEFAULT_COMM = {
    "gpipe": "free",
    "1F1B-AS": "free",
    # FBP-AS: FPGA spatial dataflow — FP and BP *timeshare* the DSP array,
    # so a (F, B) pair still costs F+B of device time (paper Table 1 keeps
    # the makespan equal to 1F1B-AS); what changes is the pipeline depth of
    # BP behind FP — doubled warm-up — hence 2x live activations and the
    # gentler 2a/(F+B) bandwidth demand.
    "FBP-AS": "free",
    "1F1B-SNO": "blocking",
    "1F1B-SO": "latency",
    "1F1B-I": "free",
    "1F1B-I-ML": "free",
    "1f1b": "free",
    "1f1b-2x": "free",
    "1f1b-interleaved": "free",
    "1f1b-interleaved-memlean": "free",
    # DAPPLE's early-backward order (== sync 1F1B) and zero-bubble H1:
    # both rely on overlapped boundary transfers
    "dapple": "free",
    "DAPPLE": "free",
    "zb-h1": "free",
    "zb_h1": "free",
    "ZB-H1": "free",
}


def simulate(schedule: str, M: int, N: int,
             F: float | Sequence[float], B: float | Sequence[float],
             SR: float = 0.0, V: int = 1,
             comm: str | None = None) -> SimResult:
    """Simulate one mini-batch of M micro-batches through N devices.

    ``V`` (>1 only for the interleaved schedules) interleaves V virtual
    stages per device; per-chunk compute time is the device time divided
    by V.  ``comm`` overrides the schedule's default communication model
    (used by the differential tests to bracket the closed forms).

    For zero-bubble plans (``zb-h1``) the ``B`` argument is the FULL
    per-micro-batch backward time of a device: the plan's input-gradient
    ``B`` ops and weight-gradient ``W`` ops each take half of it (the
    even split the closed form assumes).
    """
    Fs = list(F) if not isinstance(F, (int, float)) else [float(F)] * N
    Bs = list(B) if not isinstance(B, (int, float)) else [float(B)] * N
    assert len(Fs) == len(Bs) == N

    default_comm = _DEFAULT_COMM.get(schedule)
    if default_comm is None:
        raise ValueError(schedule)
    plan = SP.build_schedule(schedule, M, N, V)
    has_w = plan.has_w
    orders = [[(op.kind, op.m, op.vstage) for op in ops]
              for ops in plan.device_ops]
    comm = comm or default_comm
    if comm not in ("free", "latency", "blocking"):
        raise ValueError(comm)

    NS = N * V                                 # virtual stages
    bsplit = 2.0 if has_w else 1.0             # zb: B is split evenly B/W
    dur = {"F": [Fs[vs % N] / V for vs in range(NS)],
           "B": [Bs[vs % N] / V / bsplit for vs in range(NS)],
           "W": [Bs[vs % N] / V / bsplit for vs in range(NS)]}

    # --- task state ------------------------------------------------------
    f_done = [[-1.0] * NS for _ in range(M)]   # completion time of F[m][vs]
    b_done = [[-1.0] * NS for _ in range(M)]
    w_done = [[-1.0] * NS for _ in range(M)]
    f_ready = [[-1.0] * NS for _ in range(M)]  # input-activation arrival
    b_ready = [[-1.0] * NS for _ in range(M)]  # error arrival
    for m in range(M):
        f_ready[m][0] = 0.0                    # stage 0 reads local data
    dev_free = [0.0] * N
    busy = [0.0] * N                           # accumulated busy time
    ptr = [0] * N                              # next op index
    n_done = 0
    total_ops = sum(len(o) for o in orders)

    def deliver(kind: str, m: int, vs_from: int, t_prod: float):
        """Schedule the transfer of an activation/error to the neighbour."""
        if kind == "W":
            return None                        # weight grads stay local
        if kind == "F":
            if vs_from == NS - 1:
                b_ready[m][NS - 1] = t_prod    # loss: error available locally
                return None
            tgt = (m, vs_from + 1, "F")
        else:
            if vs_from == 0:
                return None
            tgt = (m, vs_from - 1, "B")
        return tgt

    pending_xfer: list[tuple[float, int, str, int, int]] = []  # (ready, m, kind, src_vs, dst_vs)

    def try_transfers(now_unused=None):
        """Fire every pending transfer, eagerly, in ready order.  Under
        ``blocking`` a transfer seizes both end-point devices for SR as
        soon as it is ready — the conservative no-overlap model: devices
        never defer a ready transfer in favour of compute."""
        nonlocal pending_xfer
        for (rdy, m, kind, src, dst) in sorted(pending_xfer):
            sd, dd = src % N, dst % N
            if comm == "free" or sd == dd:
                (f_ready if kind == "F" else b_ready)[m][dst] = rdy
            elif comm == "latency":
                (f_ready if kind == "F" else b_ready)[m][dst] = rdy + SR
            else:                           # blocking: both devices busy SR
                start = max(rdy, dev_free[sd], dev_free[dd])
                dev_free[sd] = start + SR
                dev_free[dd] = start + SR
                busy[sd] += SR
                busy[dd] += SR
                (f_ready if kind == "F" else b_ready)[m][dst] = start + SR
        pending_xfer = []

    # --- main loop: repeatedly start the globally-earliest runnable op ----
    while n_done < total_ops:
        try_transfers()
        best = None                            # (start, n, kind, m, vs)
        for n in range(N):
            if ptr[n] >= len(orders[n]):
                continue
            kind, m, vs = orders[n][ptr[n]]
            if kind == "F" and f_ready[m][vs] >= 0:
                s = max(dev_free[n], f_ready[m][vs])
            elif kind == "B" and b_ready[m][vs] >= 0 and f_done[m][vs] >= 0:
                s = max(dev_free[n], b_ready[m][vs], f_done[m][vs])
            elif kind == "W" and b_done[m][vs] >= 0:
                s = max(dev_free[n], b_done[m][vs])
            else:
                continue
            if best is None or s < best[0]:
                best = (s, n, kind, m, vs)
        assert best is not None, "pipeline deadlock (bad op order)"
        s, n, kind, m, vs = best
        d = dur[kind][vs]
        end = s + d
        dev_free[n] = end
        busy[n] += d
        if kind == "F":
            f_done[m][vs] = end
        elif kind == "B":
            b_done[m][vs] = end
        else:
            w_done[m][vs] = end
        ptr[n] += 1
        tgt = deliver(kind, m, vs, end)
        if tgt is not None:
            tm, tvs, tkind = tgt
            pending_xfer.append((end, tm, tkind, vs, tvs))
        n_done += 1

    try_transfers()
    done_rows = w_done if has_w else b_done
    makespan = max(max(r) for r in done_rows)

    # peak live activations per device: F done (or started) but the
    # residual-releasing op (B; W for zero-bubble plans) not done, summed
    # over the device's V chunks.
    peak = []
    for n in range(N):
        events = []
        for vs in range(n, NS, N):
            events += [(f_done[m][vs] - dur["F"][vs], +1) for m in range(M)]
            events += [(done_rows[m][vs], -1) for m in range(M)]
        events.sort()
        live = pk = 0
        for _, delta in events:
            live += delta
            pk = max(pk, live)
        peak.append(pk)
    idle = [makespan - busy[n] for n in range(N)]
    return SimResult(makespan=makespan, peak_live=peak, idle=idle)
