"""Discrete-event simulator for intra-batch pipeline schedules.

Re-derives the paper's Table 1/2 numbers tick-by-tick instead of trusting
the closed forms: every FP/BP of every micro-batch on every stage is a task;
stage-boundary transfers are tasks too.  Three communication models:

* ``free``     — transfers are instantaneous (paper's async figures omit SR:
                 "complete overlap by asynchronous execution").
* ``latency``  — transfers take SR on a dedicated comm engine, overlapping
                 compute (1F1B-SO's doubled warm-up makes this hideable).
* ``blocking`` — a transfer occupies *both* end-point devices for SR
                 (1F1B-SNO: synchronous execution, no overlap).

Op orders come from the schedule-plan IR (:mod:`repro.core.schedplan`):
``simulate`` builds the per-device op table once and replays it, so the
simulator, the closed forms and the SPMD runtime all execute the same
compiled order.  Interleaved 1F1B (``1F1B-I``) runs V *virtual stages*
per device (virtual stage ``v*N + n`` is chunk v of device n) in
streaming chunk-pass order — the runtime's circular ``ppermute``
schedule, closed-form makespan ``(M*V + N - 1)(F + B)/V`` for M >= N.
``1F1B-I-ML`` replays the Megatron memory-lean interleaved order (groups
of N micro-batches, warm-up ``2(N-n-1) + (V-1)N``): same makespan,
``(V-1)N`` resident-features term instead of ``(V-1)M``.  ``dapple``
replays DAPPLE's early-backward order (== synchronous 1F1B), and
``zb-h1`` replays the zero-bubble split-backward table: its ``B`` ops
(input gradient, B/2 each) propagate errors upstream while ``W`` ops
(weight gradient, B/2, no transfer) fill the drain bubbles — makespan
``M(F+B) + (N-1)(F + B/2)``.  ``zb-h2`` replays the bubble-free
hand-crafted table (makespan ``M(F+B) + (N-1)F`` at the even-split
design point) and ``zb-auto`` the automatic scheduler's table;
cost-/cap-parameterised auto tables are replayed by passing the prebuilt
:class:`~repro.core.schedplan.SchedPlan` as ``schedule``.

Gradient synchronisation is replayable too: ``grad_sync=True`` appends
the schedule-plan AR ops (one bucketed data-parallel reduce-scatter/
all-gather per device chunk, ready when the bucket's last B/W retires)
and ``ar`` gives the per-device bucket duration.  AR ops serialize on a
single shared data-axis fabric — DAPPLE's contention argument: every
stage group's all-reduce crosses the same data-axis links — so the
overlapped makespan is the single-resource schedule with per-device
release times, never worse than the sync-at-end baseline
``makespan + sum(ar)`` and strictly better whenever the drain is
staggered (any bubbled builder).

The simulator also tracks the peak number of live micro-batch activations
per device — the paper's "features memory" column; for W-bearing
(zero-bubble) plans this is read off the IR's ``peak_live()`` symbolic
replay, the same quantity the runtime's residual stash allocates — plus
each device's active window (``t_start``/``t_end``/``busy``), whose
``internal_idle`` is the schedule bubble with the fill/drain ramp
excluded (zero everywhere == bubble-free).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import schedplan as SP


@dataclasses.dataclass
class SimResult:
    makespan: float
    peak_live: list[int]          # per device: peak resident activations
    idle: list[float]             # per device: total idle (bubble) time
    t_start: list[float] = dataclasses.field(default_factory=list)
    t_end: list[float] = dataclasses.field(default_factory=list)
    busy: list[float] = dataclasses.field(default_factory=list)
    # per-op event log, one ``(start, end, kind, m, vstage)`` tuple per
    # compute op in start order — the trace the instruction-stream
    # runtime's slot assignment is differentially checked against
    events: list[tuple] = dataclasses.field(default_factory=list)
    # per-stage device widths (dp*tp chips behind each pipeline stage)
    # when the replay was costed from a width-annotated StageCosts —
    # annotation only, the durations already price the sharding
    widths: tuple = ()

    def bubble_fraction(self, stage: int = 0) -> float:
        return self.idle[stage] / self.makespan if self.makespan else 0.0

    @property
    def internal_idle(self) -> list[float]:
        """Per-device idle *inside* the device's own active window (first
        op start to last op end) — the schedule bubble proper, excluding
        the unavoidable pipeline fill/drain ramp.  A schedule is
        bubble-free exactly when this is zero everywhere (zb-h2 and
        unbounded zb-auto, for M >= 2N)."""
        return [(e - s) - b
                for s, e, b in zip(self.t_start, self.t_end, self.busy)]


# default communication model per schedule-table name (the paper's async
# figures omit SR; SNO pays it blocking, SO hides it behind compute)
_DEFAULT_COMM = {
    "gpipe": "free",
    "1F1B-AS": "free",
    # FBP-AS: FPGA spatial dataflow — FP and BP *timeshare* the DSP array,
    # so a (F, B) pair still costs F+B of device time (paper Table 1 keeps
    # the makespan equal to 1F1B-AS); what changes is the pipeline depth of
    # BP behind FP — doubled warm-up — hence 2x live activations and the
    # gentler 2a/(F+B) bandwidth demand.
    "FBP-AS": "free",
    "1F1B-SNO": "blocking",
    "1F1B-SO": "latency",
    "1F1B-I": "free",
    "1F1B-I-ML": "free",
    "1f1b": "free",
    "1f1b-2x": "free",
    "1f1b-interleaved": "free",
    "1f1b-interleaved-memlean": "free",
    # DAPPLE's early-backward order (== sync 1F1B) and the zero-bubble
    # family: all rely on overlapped boundary transfers
    "dapple": "free",
    "DAPPLE": "free",
    "zb-h1": "free",
    "zb_h1": "free",
    "ZB-H1": "free",
    "zb-h2": "free",
    "zb_h2": "free",
    "ZB-H2": "free",
    "zb-auto": "free",
    "zb_auto": "free",
    "ZB-AUTO": "free",
}


def op_durations(N: int, V: int, Fs: Sequence[float], Bs: Sequence[float],
                 wfs: Sequence[float], has_w: bool,
                 ars: Sequence[float] | None = None,
                 ar_groups: int = 1) -> dict:
    """Per-virtual-stage op durations — the single duration model shared
    by the discrete-event simulator, the instruction-stream runtime's
    timing expectations and the benchmarks.  For W-bearing plans the
    full backward ``Bs`` splits into an input-gradient ``B`` op
    (``1 - w_frac``) and a weight-gradient ``W`` op (``w_frac``); V > 1
    divides device time evenly across the device's chunks.  ``ars`` is
    the per-device gradient-sync time (the device's whole stage bucket
    crossing the data-axis fabric); each of the V chunk buckets costs
    an even 1/V share, and each of a chunk's ``ar_groups`` layer-group
    sub-buckets an even share of that."""
    NS = N * V
    dur = {"F": [Fs[vs % N] / V for vs in range(NS)],
           "B": [Bs[vs % N] / V
                 * ((1.0 - wfs[vs % N]) if has_w else 1.0)
                 for vs in range(NS)],
           "W": [Bs[vs % N] / V * wfs[vs % N] for vs in range(NS)]}
    if ars is not None:
        dur["AR"] = [ars[vs % N] / V / ar_groups for vs in range(NS)]
    return dur


def simulate(schedule: str | SP.SchedPlan, M: int, N: int,
             F: float | Sequence[float], B: float | Sequence[float],
             SR: float | Sequence[float] = 0.0, V: int = 1,
             comm: str | None = None,
             w_frac: float | Sequence[float] = 0.5,
             ar: float | Sequence[float] | None = None,
             grad_sync: bool | int = False) -> SimResult:
    """Simulate one mini-batch of M micro-batches through N devices.

    ``schedule`` is a schedule name (the op table is built via
    :func:`repro.core.schedplan.build_schedule`) or a prebuilt
    :class:`~repro.core.schedplan.SchedPlan` — the way cost- or
    cap-parameterised ``zb-auto`` tables are replayed.  ``V`` (>1 only
    for the interleaved schedules) interleaves V virtual stages per
    device; per-chunk compute time is the device time divided by V.
    ``comm`` overrides the schedule's default communication model (used
    by the differential tests to bracket the closed forms).

    Every duration knob takes a scalar or a vector: ``F``/``B`` per
    device (length N), ``SR`` per *hop* — length ``N*V - 1``, one entry
    per virtual-stage boundary (for V == 1 that is one entry per
    physical link, the heterogeneous-transceiver case hardware.py
    models) — and ``w_frac`` per device (length N).

    For zero-bubble plans (``zb-h1``/``zb-h2``/``zb-auto``) the ``B``
    argument is the FULL per-micro-batch backward time of a device;
    ``w_frac`` is the fraction of it spent in the weight-gradient ``W``
    op (default the even split the closed forms assume), the rest in the
    input-gradient ``B`` op.

    ``grad_sync=True`` appends the data-parallel gradient-sync AR ops
    (:func:`repro.core.schedplan.add_grad_sync`) before replay; ``ar``
    is the per-device sync duration — the device's stage gradient
    bucket crossing the shared data-axis fabric (scalar or length-N,
    default 0).  AR ops serialize on one fabric resource (at most one
    bucket in flight, ready buckets granted highest-device-first) and
    are unaffected by the stage-boundary ``comm`` model — the data
    axis is a different set of links than the stage rings.  An integer
    ``grad_sync=G`` emits G per-layer-group sub-buckets per (device,
    chunk), each costing an even ``ar/V/G`` share.
    """
    Fs = list(F) if not isinstance(F, (int, float)) else [float(F)] * N
    Bs = list(B) if not isinstance(B, (int, float)) else [float(B)] * N
    assert len(Fs) == len(Bs) == N
    wfs = (list(w_frac) if not isinstance(w_frac, (int, float))
           else [float(w_frac)] * N)
    if len(wfs) != N:
        raise ValueError(f"w_frac needs one entry per device ({N}), "
                         f"got {len(wfs)}")
    if not all(0.0 < wf < 1.0 for wf in wfs):
        raise ValueError(f"w_frac must be in (0, 1), got {w_frac}")
    n_hops = max(0, N * V - 1)
    SRs = (list(SR) if not isinstance(SR, (int, float))
           else [float(SR)] * n_hops)
    if len(SRs) != n_hops:
        raise ValueError(f"SR needs one entry per virtual-stage hop "
                         f"({n_hops}), got {len(SRs)}")
    if any(s < 0 for s in SRs):
        raise ValueError(f"SR must be >= 0, got {SR}")
    ars = None
    if ar is not None:
        ars = (list(ar) if not isinstance(ar, (int, float))
               else [float(ar)] * N)
        if len(ars) != N:
            raise ValueError(f"ar needs one entry per device ({N}), "
                             f"got {len(ars)}")
        if any(a < 0 for a in ars):
            raise ValueError(f"ar must be >= 0, got {ar}")

    if isinstance(schedule, SP.SchedPlan):
        plan = schedule
        if (plan.M, plan.N, plan.V) != (M, N, V):
            raise ValueError(
                f"plan {plan.name!r} is (M={plan.M}, N={plan.N}, "
                f"V={plan.V}); simulate() was asked for ({M}, {N}, {V})")
        if grad_sync:
            plan = SP.add_grad_sync(
                plan, groups=1 if grad_sync is True else int(grad_sync))
        default_comm = _DEFAULT_COMM.get(plan.name, "free")
    else:
        default_comm = _DEFAULT_COMM.get(schedule)
        if default_comm is None:
            raise ValueError(schedule)
        plan = SP.build_schedule(schedule, M, N, V, grad_sync=grad_sync)
    if plan.has_grad_sync and ars is None:
        ars = [0.0] * N
    has_w = plan.has_w
    orders = [[(op.kind, op.m, op.vstage) for op in ops]
              for ops in plan.device_ops]
    comm = comm or default_comm
    if comm not in ("free", "latency", "blocking"):
        raise ValueError(comm)

    NS = N * V                                 # virtual stages
    dur = op_durations(N, V, Fs, Bs, wfs, has_w, ars,
                       ar_groups=plan.grad_sync_groups or 1)

    # --- task state ------------------------------------------------------
    f_done = [[-1.0] * NS for _ in range(M)]   # completion time of F[m][vs]
    b_done = [[-1.0] * NS for _ in range(M)]
    w_done = [[-1.0] * NS for _ in range(M)]
    f_ready = [[-1.0] * NS for _ in range(M)]  # input-activation arrival
    b_ready = [[-1.0] * NS for _ in range(M)]  # error arrival
    for m in range(M):
        f_ready[m][0] = 0.0                    # stage 0 reads local data
    dev_free = [0.0] * N
    busy = [0.0] * N                           # accumulated busy time
    t_start: list = [None] * N                 # first compute-op start
    t_end = [0.0] * N                          # last compute-op end
    ptr = [0] * N                              # next op index
    n_done = 0
    total_ops = sum(len(o) for o in orders)
    event_log: list[tuple] = []

    def deliver(kind: str, m: int, vs_from: int, t_prod: float):
        """Schedule the transfer of an activation/error to the neighbour."""
        if kind in ("W", "AR"):
            return None                        # no stage-boundary transfer
        if kind == "F":
            if vs_from == NS - 1:
                b_ready[m][NS - 1] = t_prod    # loss: error available locally
                return None
            tgt = (m, vs_from + 1, "F")
        else:
            if vs_from == 0:
                return None
            tgt = (m, vs_from - 1, "B")
        return tgt

    pending_xfer: list[tuple[float, int, str, int, int]] = []  # (ready, m, kind, src_vs, dst_vs)

    def try_transfers(now_unused=None):
        """Fire every pending transfer, eagerly, in ready order.  Under
        ``blocking`` a transfer seizes both end-point devices for SR as
        soon as it is ready — the conservative no-overlap model: devices
        never defer a ready transfer in favour of compute."""
        nonlocal pending_xfer
        for (rdy, m, kind, src, dst) in sorted(pending_xfer):
            sd, dd = src % N, dst % N
            sr = SRs[min(src, dst)]         # the hop's own link time
            if comm == "free" or sd == dd:
                (f_ready if kind == "F" else b_ready)[m][dst] = rdy
            elif comm == "latency":
                (f_ready if kind == "F" else b_ready)[m][dst] = rdy + sr
            else:                           # blocking: both devices busy SR
                start = max(rdy, dev_free[sd], dev_free[dd])
                dev_free[sd] = start + sr
                dev_free[dd] = start + sr
                busy[sd] += sr
                busy[dd] += sr
                (f_ready if kind == "F" else b_ready)[m][dst] = start + sr
        pending_xfer = []

    # --- main loop: repeatedly start the globally-earliest runnable op ----
    # AR ops share one data-axis fabric: at most one gradient bucket in
    # flight at a time; among equally-ready buckets the highest device
    # (deepest stage, first to drain) goes first — matching the tick
    # lowering's greedy grant.  Any work-conserving grant order gives
    # the same single-resource makespan; the tie-break only pins the
    # event order the conformance tests compare against ``slot_of``.
    fabric_free = 0.0
    ar_end = 0.0
    while n_done < total_ops:
        try_transfers()
        best = None                            # (key, n, kind, m, vs)
        for n in range(N):
            if ptr[n] >= len(orders[n]):
                continue
            kind, m, vs = orders[n][ptr[n]]
            if kind == "F" and f_ready[m][vs] >= 0:
                s = max(dev_free[n], f_ready[m][vs])
            elif kind == "B" and b_ready[m][vs] >= 0 and f_done[m][vs] >= 0:
                s = max(dev_free[n], b_ready[m][vs], f_done[m][vs])
            elif kind == "W" and b_done[m][vs] >= 0:
                s = max(dev_free[n], b_done[m][vs])
            elif kind == "AR":
                s = max(dev_free[n], fabric_free)
            else:
                continue
            key = (s, -n if kind == "AR" else 0)
            if best is None or key < best[0]:
                best = (key, n, kind, m, vs)
        assert best is not None, "pipeline deadlock (bad op order)"
        (s, _), n, kind, m, vs = best
        d = dur[kind][vs]
        end = s + d
        event_log.append((s, end, kind, m, vs))
        dev_free[n] = end
        busy[n] += d
        if t_start[n] is None:
            t_start[n] = s
        t_end[n] = end
        if kind == "F":
            f_done[m][vs] = end
        elif kind == "B":
            b_done[m][vs] = end
        elif kind == "W":
            w_done[m][vs] = end
        else:
            fabric_free = end
            ar_end = max(ar_end, end)
        ptr[n] += 1
        tgt = deliver(kind, m, vs, end)
        if tgt is not None:
            tm, tvs, tkind = tgt
            pending_xfer.append((end, tm, tkind, vs, tvs))
        n_done += 1

    try_transfers()
    done_rows = w_done if has_w else b_done
    makespan = max(ar_end, max(max(r) for r in done_rows))

    # peak live activations per device.  W-bearing plans take the row
    # straight from the IR's symbolic replay — the schedule-plan table is
    # the single source of truth for what the runtime's residual stash
    # allocates (pinned in tests/test_simulator_vs_closed_form.py); the
    # event-time reconstruction below is kept for two-op plans, whose
    # differential tests grant the greedy scheduler one-op-ahead slack.
    if has_w:
        peak = plan.peak_live()
    else:
        peak = []
        for n in range(N):
            events = []
            for vs in range(n, NS, N):
                events += [(f_done[m][vs] - dur["F"][vs], +1)
                           for m in range(M)]
                events += [(done_rows[m][vs], -1) for m in range(M)]
            events.sort()
            live = pk = 0
            for _, delta in events:
                live += delta
                pk = max(pk, live)
            peak.append(pk)
    idle = [makespan - busy[n] for n in range(N)]
    return SimResult(makespan=makespan, peak_live=peak, idle=idle,
                     t_start=[0.0 if s is None else s for s in t_start],
                     t_end=t_end, busy=list(busy), events=event_log)


def simulate_costs(schedule: str | SP.SchedPlan, M: int, N: int,
                   costs: SP.StageCosts,
                   comm: str | None = None,
                   ar: float | Sequence[float] | None = None,
                   grad_sync: bool | int = False) -> SimResult:
    """Replay a (V == 1) schedule under a first-class
    :class:`~repro.core.schedplan.StageCosts` vector: per-device F and
    full-backward durations, per-device ``w_frac`` split, per-hop SR.
    The default comm model is ``latency`` when any hop has a nonzero SR
    (a dedicated comm engine paying each boundary's own transfer time),
    ``free`` otherwise — matching the cost-shaped ``zb-auto`` builder's
    arrival model, so a builder's internal makespan and this replay
    agree.  The costs' per-stage ``width`` annotation (dp*tp chips per
    stage) is carried onto the result — the durations already price
    the sharding, so the replay itself is width-agnostic."""
    if costs.n != N:
        raise ValueError(f"costs are for {costs.n} devices, "
                         f"simulate_costs was asked for N={N}")
    sr = list(costs.sr_hops)
    if comm is None:
        comm = "latency" if any(s > 0 for s in sr) else "free"
    res = simulate(schedule, M, N, list(costs.F), list(costs.B_full),
                   sr, V=1, comm=comm, w_frac=list(costs.w_frac),
                   ar=ar, grad_sync=grad_sync)
    res.widths = tuple(costs.widths)
    return res
