"""Closed-form pipeline-schedule cost models — paper Tables 1 and 2,
extended with interleaved virtual-stage, early-backward and zero-bubble
schedules.

Ten schedules:

* ``1F1B-AS`` — async (FPGA-style) one-forward-one-backward.
* ``FBP-AS``  — async, FP and BP computed in parallel on each accelerator
  (FPDeep).  Same makespan, double activation memory, lower bandwidth demand.
* ``1F1B-SNO`` — synchronous, communication NOT overlapped with compute.
* ``1F1B-SO``  — synchronous, overlapped via doubled warm-up micro-batches
  (the paper's contribution). Double activation memory vs SNO.
* ``1F1B-I``  — async interleaved 1F1B over V *virtual stages* per device
  (beyond-paper; the Megatron/DAPPLE interleaving direction in PAPERS.md).
* ``1F1B-I-ML`` — memory-lean interleaved 1F1B (Megatron ordering from
  "Memory-Efficient Pipeline-Parallel DNN Training"): micro-batches advance
  in groups of N with warm-up ``2(N-n-1) + (V-1)N``, cutting the resident
  features term from ``(V-1)M`` to ``(V-1)N`` at the same makespan.
* ``DAPPLE`` — DAPPLE's early-backward synchronous schedule (arXiv
  2007.01045): warm-up ``N - i + 1`` then strict 1F1B alternation.  Same
  rows as 1F1B-AS; kept as its own entry because the runtime now executes
  its backward order as first-class ticks.
* ``ZB-H1`` — zero-bubble H1 (arXiv 2211.05953): every backward splits
  into an input-gradient op (B/2) that propagates the error and a
  weight-gradient op (B/2) that fills drain bubbles.  Makespan
  ``M(F+B) + (N-1)(F + B/2)`` — the ``(N-1)B/2`` saved is exactly the
  weight-grad work pulled off the critical path — at 1F1B's
  ``N - i + 1`` features row.
* ``ZB-H2`` — zero-bubble H2: warm-up deepens to ``2(N-i+1) - 1`` and
  weight-gradients bank past the drain, removing the whole flush bubble:
  makespan ``M(F+B) + (N-1)F`` (exact at the even-split design point
  ``B = 2F``; the work-and-fill floor elsewhere) at ~2x 1F1B's memory.
* ``ZB-AUTO`` — the automatic zero-bubble scheduler: a cost-driven list
  scheduler over F/B/W placement under a peak-live ``mem_limit`` knob;
  reports the scheduled (replayed) makespan, interpolating ZB-H1 (1F1B
  cap) through ZB-H2 to fully bubble-free (unbounded cap, M-deep memory).

The op orders behind these rows live in :mod:`repro.core.schedplan` (the
schedule-plan IR); the features rows here are the algebraic form of
``SchedPlan.peak_live()``'s symbolic table replay, and the differential
suite pins the two (and the discrete-event simulator) together.

Symbols (paper):  M = micro-batches per mini-batch, N = pipeline stages,
F/B = per-micro-batch FP/BP compute time of one (balanced) stage,
SR = send/receive time of one stage boundary, a = activation bytes of one
stage boundary (per micro-batch), w = weight bytes of one stage,
i = stage index 1..N.

1F1B-I symbols and formulas (V = virtual-stage interleave depth):

* Each device owns V non-contiguous layer chunks; chunk v of device n is
  *virtual stage* v*N + n, so one micro-batch loops the device daisy chain
  V times.  A chunk costs F/V (FP) and B/V (BP).
* Makespan      t = (M*V + N - 1) * (F + B) / V
                  = M*(F+B) + (N-1)*(F+B)/V  — the flush bubble shrinks
  by the interleave depth V (requires M >= N so every chunk pass streams
  without stalling; the explorer gates candidates on this).
* Bubble        (N - 1) / (M*V + N - 1)   — strictly below 1F1B-AS's
                (N - 1) / (M + N - 1) for V > 1.
* Features      min(M*V, (V-1)*M + N - i + 1) live chunk activations on
  device i: the first V-1 passes of every micro-batch stay resident until
  their backward returns, plus the usual 1F1B (N - i + 1) in-flight window.
  V = 1 reduces exactly to the 1F1B-AS row.
* Bandwidth     V*a/F — the boundary is crossed once per chunk, i.e. V
  times more traffic per micro-batch in the same compute time.
"""
from __future__ import annotations

import dataclasses
import functools
import math

from repro.core.schedplan import StageCosts


@dataclasses.dataclass(frozen=True)
class ScheduleEval:
    name: str
    minibatch_time: float
    bubble_fraction: float
    features_memory: tuple[float, ...]   # per stage i=1..N
    weights_memory: float                # per stage (2w: weights + grads)
    bandwidth_demand: float              # bytes/s needed to fully overlap
    V: int = 1                           # virtual-stage interleave depth


def _feat(mult: int, N: int, a: float) -> tuple[float, ...]:
    return tuple(float(mult * (N - i + 1)) * a for i in range(1, N + 1))


def eval_1f1b_as(M: int, N: int, F: float, B: float, SR: float,
                 a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B)
    return ScheduleEval(
        name="1F1B-AS", minibatch_time=t,
        bubble_fraction=(N - 1) / (M + N - 1),
        features_memory=_feat(1, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_fbp_as(M: int, N: int, F: float, B: float, SR: float,
                a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B)
    return ScheduleEval(
        name="FBP-AS", minibatch_time=t,
        bubble_fraction=(N - 1) / (M + N - 1),
        features_memory=_feat(2, N, a), weights_memory=2 * w,
        bandwidth_demand=(2 * a / (F + B)) if F + B > 0 else float("inf"))


def eval_1f1b_sno(M: int, N: int, F: float, B: float, SR: float,
                  a: float, w: float) -> ScheduleEval:
    extra = (N + M - 2 - math.ceil((M - 1) / N)) * 2 * SR
    t = (M + N - 1) * (F + B) + extra
    bubble = ((N - 1) * (F + B + 2 * SR)
              + (M - 1 - math.ceil((M - 1) / N)) * 2 * SR) / t if t else 0.0
    return ScheduleEval(
        name="1F1B-SNO", minibatch_time=t, bubble_fraction=bubble,
        features_memory=_feat(1, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_1f1b_so(M: int, N: int, F: float, B: float, SR: float,
                 a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B) + (N - 1) * 2 * SR
    bubble = (N - 1) * (F + B + 2 * SR) / t if t else 0.0
    return ScheduleEval(
        name="1F1B-SO", minibatch_time=t, bubble_fraction=bubble,
        features_memory=_feat(2, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_dapple(M: int, N: int, F: float, B: float, SR: float,
                a: float, w: float) -> ScheduleEval:
    """DAPPLE early-backward schedule (arXiv 2007.01045): warm-up
    ``N - i + 1`` forwards then strict 1F1B alternation.  The rows ARE
    1F1B-AS's (derived, so they can never diverge) — the point of the
    entry is that the runtime now *executes* the early-backward order
    (first-class B ticks), so the row names the schedule it actually
    runs."""
    return dataclasses.replace(eval_1f1b_as(M, N, F, B, SR, a, w),
                               name="DAPPLE")


def eval_zb_h1(M: int, N: int, F: float, B: float, SR: float,
               a: float, w: float) -> ScheduleEval:
    """Zero-bubble H1 (arXiv 2211.05953): the backward splits evenly into
    an input-gradient op ``b = B/2`` (sends the error upstream) and a
    weight-gradient op ``w = B/2`` (no boundary edges; fills what would
    otherwise be drain bubbles).

    Makespan ``M(F + B) + (N-1)(F + B/2)`` — differentially pinned against
    the op-table replay in the simulator: errors propagate upstream at
    ``b = B/2`` per hop instead of the full ``B``, and each drain wait is
    filled by exactly one W, so ``(N-1) B/2`` of weight-grad work leaves
    the critical path.  Peak resident features stay at 1F1B's
    ``N - i + 1`` row (each W directly follows its B).  Bubble strictly
    below 1F1B-AS for N > 1."""
    b = B / 2.0
    t = M * (F + B) + (N - 1) * (F + b)
    bubble = (N - 1) * (F + b) / t if t else 0.0
    return ScheduleEval(
        name="ZB-H1", minibatch_time=t, bubble_fraction=bubble,
        features_memory=_feat(1, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_zb_h2(M: int, N: int, F: float, B: float, SR: float,
               a: float, w: float) -> ScheduleEval:
    """Zero-bubble H2 (arXiv 2211.05953): the bubble-free hand-crafted
    point.  Warm-up deepens to ``2(N-i+1) - 1`` forwards and the
    downstream devices bank weight-gradients past the drain, so after the
    unavoidable ``(N-1)F`` fill ramp no device idles:

        makespan  t = M(F + B) + (N-1) F

    — the whole ``(N-1)(F + B)`` 1F1B flush bubble is gone, paid for with
    the ``max(2(N-i+1)-1, i-1+ceil((N+1)/2))`` features row (~2x 1F1B's
    warm-up memory; ZB-H1 keeps 1F1B's row but only halves the drain
    term).  The reported makespan is the op-table *replay* (so the
    explorer ranks an achievable number): it EQUALS the closed form above
    at the even-split design point ``B == 2F`` for ``M >= 2N - 1`` —
    differentially pinned — while at other cost ratios the closed form is
    only the work-and-fill *floor* (a strict lower bound any V=1 schedule
    obeys) that the static table's unit-cost W weave may miss; the
    cost-adaptive ``ZB-AUTO`` entry adapts the weave instead."""
    from repro.core.schedplan import build_zb_h2, live_activation_counts
    from repro.core.simulator import simulate
    t = simulate(build_zb_h2(M, N), M, N, F, B, 0.0).makespan
    bubble = 1.0 - M * (F + B) / t if t else 0.0
    feats = tuple(float(c) * a
                  for c in live_activation_counts("ZB-H2", M, N))
    return ScheduleEval(
        name="ZB-H2", minibatch_time=t, bubble_fraction=bubble,
        features_memory=feats, weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_zb_auto(M: int, N: int, F: float, B: float, SR: float,
                 a: float, w: float, mem_limit=None,
                 w_frac: float = 0.5) -> ScheduleEval:
    """Automatic zero-bubble scheduler (arXiv 2211.05953's heuristic):
    :func:`repro.core.schedplan.build_zb_auto` places F/B/W ops under the
    ``mem_limit`` peak-live cap with the actual op costs, and this entry
    reports the *scheduled* makespan — the discrete-event replay of the
    emitted table, not a formula — plus the peak-live row from the IR's
    symbolic replay.  ``B`` is the full backward; ``w_frac`` of it is the
    weight-gradient half.  With an unbounded cap the steady state is
    bubble-free (only the fill/drain ramp remains; peak-live climbs to
    M); under the 1F1B cap the table IS ZB-H1's, so this entry always
    interpolates the zero-bubble family along the memory axis."""
    from repro.core.schedplan import build_zb_auto
    from repro.core.simulator import simulate
    plan = build_zb_auto(M, N, costs=(F, B * (1 - w_frac), B * w_frac),
                         mem_limit=mem_limit)
    sim = simulate(plan, M, N, F, B, 0.0, w_frac=w_frac)
    t = sim.makespan
    feats = tuple(float(c) * a for c in plan.peak_live())
    return ScheduleEval(
        name="ZB-AUTO", minibatch_time=t,
        bubble_fraction=1.0 - M * (F + B) / t if t else 0.0,
        features_memory=feats, weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


# ---------------------------------------------------------------------------
# Heterogeneous per-device cost forms (the StageCosts vector interface).
#
# BaPipe's §V clusters are heterogeneous; the scalar forms above see only
# the bottleneck device.  Each ``eval_*_hetero`` takes the full
# :class:`~repro.core.schedplan.StageCosts` vector and reports the
# *scheduled* makespan — the discrete-event replay of the schedule's op
# table under per-device durations, free comm (the same async-overlap
# premise as the uniform Table-1 forms; per-hop SR is carried for the
# SR-aware builder/simulator path).  A uniform vector delegates to the
# scalar closed form, so the reduction is bit-exact; elsewhere the
# analytic generalisation :func:`hetero_makespan_floor` brackets the
# replay from below (exact again at each form's design point), the same
# premise-plus-bracket contract as the 1F1B-I latency form.
# ---------------------------------------------------------------------------

def hetero_makespan_floor(M: int, costs: StageCosts,
                          drain: str = "full") -> float:
    """Generalised bottleneck lower bound for a heterogeneous chain —
    every path is forced by device serialisation plus chain
    dependencies, so each variant bounds its schedule's replay from
    below at ANY cost vector and recovers the uniform closed form
    exactly at its design point.

    * ``"none"``  — work-and-fill, valid for every V=1 schedule:
      ``max_n [ sum_{k<n} F_k + M (F_n + B_n + W_n) ]`` (micro-batch 0
      cannot reach device n before the upstream forwards run once, and
      the device serialises all its own work).  Uniform:
      ``M(F+B) + (N-1)F`` — the ZB-H2 / unbounded-zb-auto form.
    * ``"full"``  — two-op schedules (1F1B/DAPPLE): after device n's
      last backward the error recrosses the upstream devices at the
      FULL backward per hop:
      ``max_n [ sum_{k<n} F_k + M (F_n + B_n + W_n) + sum_{k<n} (B_k
      + W_k) ]``.  Uniform: ``(M+N-1)(F+B)``.
    * ``"input"`` — the ZB-H1 drain shape: device n's last
      input-gradient comes after M forwards, M input-gradients and
      M-1 interleaved weight-gradients; the error then recrosses
      upstream at the input-gradient half per hop, and stage 0 still
      owes its final weight-gradient:
      ``max_n [ sum_{k<n} F_k + M (F_n + B_n) + (M-1) W_n
      + sum_{k<n} B_k + W_0 ]``.  Uniform even split:
      ``M(F+B) + (N-1)(F + B/2)``."""
    if drain not in ("full", "input", "none"):
        raise ValueError(f"drain must be full|input|none, got {drain!r}")
    F, W, Bf, Bi = costs.F, costs.W, costs.B_full, costs.B
    best = 0.0
    for n in range(costs.n):
        if drain == "input":
            t = (sum(F[:n]) + M * (F[n] + Bi[n]) + (M - 1) * W[n]
                 + sum(Bi[:n]) + W[0])
        else:
            t = sum(F[:n]) + M * (F[n] + Bf[n])
            if drain == "full":
                t += sum(Bf[:n])
        best = max(best, t)
    return best


@functools.lru_cache(maxsize=256)
def _replay_hetero(name: str, M: int, N: int, costs: StageCosts,
                   mem_limit=None, V: int = 1, comm: str | None = None):
    """(plan, SimResult) of a builder's table under per-device durations
    — the scheduled heterogeneous makespan the hetero evals report.
    ``zb-auto`` builds the cost-shaped table from the vector; ``V > 1``
    replays the interleaved builders' chunked tables; ``comm`` selects
    the simulator's communication model (the sync forms replay under
    ``blocking``/``latency`` with the vector's own per-hop SR; the
    default ``None`` keeps the schedule's free-comm async premise, SR
    stripped).  Cached: the explorer evaluates several schedules per
    candidate partition and DAPPLE shares 1F1B's table, so identical
    (table, costs) replays recur (StageCosts is frozen, so the key is
    by value)."""
    from repro.core import schedplan as SP
    from repro.core.simulator import simulate
    if name == "zb-auto":
        plan = SP.build_zb_auto(
            M, N, costs=(list(costs.F), list(costs.B), list(costs.W)),
            mem_limit=mem_limit)
    else:
        plan = SP.build_schedule(name, M, N, V)
    SR = (list(costs.sr_hops) if comm in ("latency", "blocking")
          else 0.0)
    sim = simulate(plan, M, N, list(costs.F), list(costs.B_full), SR,
                   V=V, comm=comm, w_frac=list(costs.w_frac))
    return plan, sim


def _hetero_eval(name: str, M: int, N: int, costs: StageCosts,
                 a: float, w: float, sim, feats) -> ScheduleEval:
    work = max(f + b for f, b in zip(costs.F, costs.B_full))
    t = sim.makespan
    return ScheduleEval(
        name=name, minibatch_time=t,
        bubble_fraction=1.0 - M * work / t if t else 0.0,
        features_memory=feats, weights_memory=2 * w,
        bandwidth_demand=(a / min(costs.F)) if min(costs.F) > 0
        else float("inf"))


def eval_1f1b_as_hetero(M: int, N: int, costs: StageCosts,
                        a: float, w: float) -> ScheduleEval:
    """1F1B-AS under a per-device cost vector: the replayed op-table
    makespan (>= :func:`hetero_makespan_floor` with the full-backward
    drain; equal to it for uniform vectors, where this delegates)."""
    if costs.uniform:
        return eval_1f1b_as(M, N, costs.F[0], costs.B_full[0],
                            max(costs.sr_hops, default=0.0), a, w)
    _, sim = _replay_hetero("1f1b", M, N, costs)
    return _hetero_eval("1F1B-AS", M, N, costs, a, w, sim, _feat(1, N, a))


def eval_fbp_as_hetero(M: int, N: int, costs: StageCosts,
                       a: float, w: float) -> ScheduleEval:
    """FBP-AS (doubled warm-up) under a per-device cost vector: same
    replayed makespan story as 1F1B-AS at the 2x features row and the
    gentler ``2a/(F+B)`` bandwidth demand."""
    if costs.uniform:
        return eval_fbp_as(M, N, costs.F[0], costs.B_full[0],
                           max(costs.sr_hops, default=0.0), a, w)
    _, sim = _replay_hetero("1f1b-2x", M, N, costs)
    ev = _hetero_eval("FBP-AS", M, N, costs, a, w, sim, _feat(2, N, a))
    fb = min(f + b for f, b in zip(costs.F, costs.B_full))
    return dataclasses.replace(
        ev, bandwidth_demand=(2 * a / fb) if fb > 0 else float("inf"))


def eval_dapple_hetero(M: int, N: int, costs: StageCosts,
                       a: float, w: float) -> ScheduleEval:
    """DAPPLE == synchronous 1F1B (derived, as in the scalar forms)."""
    return dataclasses.replace(eval_1f1b_as_hetero(M, N, costs, a, w),
                               name="DAPPLE")


def eval_zb_h1_hetero(M: int, N: int, costs: StageCosts,
                      a: float, w: float) -> ScheduleEval:
    """Zero-bubble H1 under a per-device cost vector: the split-backward
    table replayed at each device's own (F, B, W) — errors cross hop k
    after only ``B_k`` (not ``B_k + W_k``) of work.  Uniform even-split
    vectors delegate to the exact ``M(F+B) + (N-1)(F + B/2)`` form."""
    if costs.uniform and costs.even_split:
        return eval_zb_h1(M, N, costs.F[0], costs.B_full[0],
                          max(costs.sr_hops, default=0.0), a, w)
    _, sim = _replay_hetero("zb-h1", M, N, costs)
    return _hetero_eval("ZB-H1", M, N, costs, a, w, sim, _feat(1, N, a))


def eval_zb_h2_hetero(M: int, N: int, costs: StageCosts,
                      a: float, w: float) -> ScheduleEval:
    """Zero-bubble H2 under a per-device cost vector: the hand-crafted
    bubble-free table replayed at per-device durations, bracketed below
    by the work-and-fill floor (``drain="none"``); uniform even-split
    vectors delegate to :func:`eval_zb_h2`."""
    if costs.uniform and costs.even_split:
        return eval_zb_h2(M, N, costs.F[0], costs.B_full[0],
                          max(costs.sr_hops, default=0.0), a, w)
    from repro.core.schedplan import live_activation_counts
    _, sim = _replay_hetero("zb-h2", M, N, costs)
    feats = tuple(float(c) * a
                  for c in live_activation_counts("ZB-H2", M, N))
    return _hetero_eval("ZB-H2", M, N, costs, a, w, sim, feats)


def eval_zb_auto_hetero(M: int, N: int, costs: StageCosts,
                        a: float, w: float,
                        mem_limit=None) -> ScheduleEval:
    """The automatic zero-bubble scheduler fed the *vector*: the greedy
    shapes its F/B/W table by each device's measured costs (and the
    builder's scalar-collapse portfolio guarantees the result is never
    worse than the table the old ``max``-collapsed interface would have
    produced, replayed at the true costs).  Reports the scheduled
    makespan plus the emitted table's peak-live row.  Uniform vectors
    delegate to :func:`eval_zb_auto` (any per-device ``w_frac``)."""
    if costs.uniform:
        return eval_zb_auto(M, N, costs.F[0], costs.B_full[0],
                            max(costs.sr_hops, default=0.0), a, w,
                            mem_limit=mem_limit, w_frac=costs.w_frac[0])
    if mem_limit is not None and not isinstance(mem_limit, (int, float)):
        mem_limit = tuple(mem_limit)     # hashable for the replay cache
    plan, sim = _replay_hetero("zb-auto", M, N, costs,
                               mem_limit=mem_limit)
    feats = tuple(float(c) * a for c in plan.peak_live())
    return _hetero_eval("ZB-AUTO", M, N, costs, a, w, sim, feats)


def eval_1f1b_interleaved(M: int, N: int, F: float, B: float, SR: float,
                          a: float, w: float, V: int = 2) -> ScheduleEval:
    """Interleaved 1F1B (see module docstring).  ``F``/``B``/``a``/``w`` are
    whole-device quantities (summed over the device's V chunks); the bubble
    shrinks by V while boundary traffic grows by V."""
    if V < 1:
        raise ValueError(f"V must be >= 1, got {V}")
    if M < N:
        # same precondition the simulator enforces: with fewer micro-batches
        # than devices the chunk passes cannot stream and this closed form
        # is an unachievable lower bound
        raise ValueError(f"1F1B-I needs M >= N to stream chunk passes "
                         f"(got M={M}, N={N})")
    t = (M * V + N - 1) * (F + B) / V
    feats = tuple(
        float(min(M * V, (V - 1) * M + (N - i + 1))) * a
        for i in range(1, N + 1))
    return ScheduleEval(
        name="1F1B-I", minibatch_time=t,
        bubble_fraction=(N - 1) / (M * V + N - 1),
        features_memory=feats, weights_memory=2 * w,
        bandwidth_demand=(V * a / F) if F > 0 else float("inf"),
        V=V)


def eval_1f1b_interleaved_memlean(M: int, N: int, F: float, B: float,
                                  SR: float, a: float, w: float,
                                  V: int = 2) -> ScheduleEval:
    """Memory-lean interleaved 1F1B (Megatron ordering; see
    :func:`repro.core.schedplan.build_1f1b_interleaved_memlean`).

    Micro-batches advance in groups of N, cycling the V chunks inside each
    group, with warm-up ``2(N - n - 1) + (V-1)N``.  Makespan and bubble are
    identical to the streaming ``1F1B-I`` order, but the per-device peak
    resident features — derived by replaying the op table symbolically —
    drop to ``min(M*V, 2(N-i) + (V-1)N + 1)`` chunk activations: the
    ``(V-1)M`` term becomes ``(V-1)N``, so the row no longer grows with
    the micro-batch count.  Requires ``M % N == 0`` (Megatron's group
    constraint, which is also what lets the runtime consume every ring
    return the tick it arrives, deleting the [M, ...] park buffer)."""
    if V < 1:
        raise ValueError(f"V must be >= 1, got {V}")
    if M < N or M % N != 0:
        raise ValueError(
            f"1F1B-I-ML needs M % N == 0 (micro-batch groups of the "
            f"pipeline depth), got M={M}, N={N}")
    from repro.core.schedplan import live_activation_counts
    t = (M * V + N - 1) * (F + B) / V
    feats = tuple(float(c) * a for c in
                  live_activation_counts("1F1B-I-ML", M, N, V))
    return ScheduleEval(
        name="1F1B-I-ML", minibatch_time=t,
        bubble_fraction=(N - 1) / (M * V + N - 1),
        features_memory=feats, weights_memory=2 * w,
        bandwidth_demand=(V * a / F) if F > 0 else float("inf"),
        V=V)


def latency_hops_1f1b_interleaved(M: int, N: int, V: int = 1) -> int:
    """Number of SR-latency hops on the 1F1B-I critical path under the
    ``latency`` comm model (transfers on a dedicated engine, SR each):

    ``2(N-1)`` fill/drain hops plus a warm-up->steady handover that
    zigzags between neighbouring saturated devices, collecting two hops
    per micro-batch except once every N micro-batches when the 1F1B
    phase realigns — ``2(M - 2 - floor((M-2)/N))`` in total.  At
    ``M == N`` (V > 1) the stream is tight: every one of the ``N(V-1)``
    chunk ring-returns sits on the critical path too (2 hops each).

    Exact (differentially pinned over randomized sweeps) whenever the
    per-hop latency is hideable: ``SR <= hideable_sr_1f1b_interleaved``.
    """
    if N <= 1:
        return 0
    hops = 2 * (M + N - 3 - (M - 2) // N)
    if M == N:
        hops += 2 * N * (V - 1)
    return hops


def hideable_sr_1f1b_interleaved(M: int, N: int, V: int, F: float,
                                 B: float) -> float:
    """Largest per-hop SR for which :func:`eval_1f1b_interleaved_latency`
    is exact (the paper-style "comm hideable" premise, as the seed suite's
    1F1B-SO pin clamps ``SR <= min(F, B)/2``): the zigzag critical path
    tolerates ``min(F, B)/(3V)`` per hop, and for V > 1 the chunk ring
    return must come back within its ``(M - N)``-element slack,
    ``(M - N) min(F, B)/(NV)``."""
    cap = min(F, B) / (3.0 * V)
    if V > 1 and M > N:
        cap = min(cap, (M - N) * min(F, B) / (N * V))
    return cap


def eval_1f1b_interleaved_latency(M: int, N: int, F: float, B: float,
                                  SR: float, a: float, w: float,
                                  V: int = 2) -> ScheduleEval:
    """1F1B-I under the ``latency`` comm model: the free-comm makespan
    plus ``SR`` per critical-path hop (:func:`latency_hops_1f1b_interleaved`).
    Exact for ``SR <= hideable_sr_1f1b_interleaved(M, N, V, F, B)``;
    beyond it transfers stall the stream and the value is a lower bound
    (the ``blocking`` model brackets from above)."""
    ev = eval_1f1b_interleaved(M, N, F, B, SR, a, w, V=V)
    t = ev.minibatch_time + latency_hops_1f1b_interleaved(M, N, V) * SR
    return dataclasses.replace(
        ev, minibatch_time=t,
        bubble_fraction=1.0 - M * V * (F + B) / V / t if t else 0.0)


def blocking_stall_1f1b_interleaved(M: int, N: int) -> float:
    """Rendezvous stalls on the 1F1B-I (V = 1) critical path under the
    ``blocking`` comm model, in units of the op cost ``c`` at the
    ``F == B == c`` design point.

    A blocking send has no transfer engine: the producer WAITS until its
    consumer posts the matching recv, so even as ``SR -> 0`` the warm-up
    wavefront serializes device by device and the steady-state zigzag
    collects one extra rendezvous per micro-batch.  Fitted and then
    differentially pinned per-M (dense sweeps over ``N <= 8``,
    ``M <= 5N``), the stall count is affine in M with a
    triangular-number offset::

        g(M, N) = M + N(N - 1)/2 - 2        (N >= 2, N != 3)
        g(M, 3) = 2M - 2                    (the depth-3 anomaly)
        g(M, N <= 1) = 0

    At N == 3 the ring is short enough that BOTH neighbours of the
    middle device rendezvous with it every cycle — the stall count
    doubles its M slope instead of gaining the triangular offset."""
    if N <= 1:
        return 0.0
    if N == 3:
        return 2.0 * M - 2.0
    return float(M + N * (N - 1) // 2 - 2)


def blocking_hops_1f1b_interleaved(M: int, N: int) -> int:
    """Number of SR-latency hops on the 1F1B-I (V = 1) critical path
    under the ``blocking`` comm model — the coefficient of SR in the
    affine makespan, companion to :func:`blocking_stall_1f1b_interleaved`::

        h(M, N) = 2M + (N + 1)(N + 2)/2 - 6     (N >= 2, N != 3)
        h(M, 3) = 3M + 1
        h(M, N <= 1) = 0

    Blocking transfers put MORE hops on the path than the latency
    model's :func:`latency_hops_1f1b_interleaved` (compare ``~2M + N^2/2``
    against ``~2(M + N)``): with no engine to overlap into, every
    rendezvous the stall count ``g`` serializes also pays its wire time."""
    if N <= 1:
        return 0
    if N == 3:
        return 3 * M + 1
    return 2 * M + (N + 1) * (N + 2) // 2 - 6


def blockable_sr_1f1b_interleaved(M: int, N: int, F: float,
                                  B: float) -> float:
    """Largest per-hop SR for which :func:`eval_1f1b_interleaved_blocking`
    is exact (the blocking twin of :func:`hideable_sr_1f1b_interleaved`).
    The affine region's breakpoint was binary-searched per (M, N): depth
    1-2 rings are affine for ALL SR, depth 3 up to ``min(F, B)``, and
    deeper rings shrink like 1/M — ``min(F, B)/(M - 2)`` at N == 4 and
    ``min(F, B)/(2M - 6)`` for N >= 5 (exact integer reciprocals at
    every probed (M, N); past them a second rendezvous chain overtakes
    the pinned one and the makespan leaves the affine piece)."""
    if N <= 2:
        return float("inf")
    if N == 3:
        return min(F, B)
    if N == 4:
        return min(F, B) / (M - 2)
    return min(F, B) / (2 * M - 6)


def eval_1f1b_interleaved_blocking(M: int, N: int, F: float, B: float,
                                   SR: float, a: float,
                                   w: float) -> ScheduleEval:
    """1F1B-I under the ``blocking`` comm model (V = 1): the free-comm
    makespan plus ``g`` rendezvous stalls of ``min(F, B)`` each
    (:func:`blocking_stall_1f1b_interleaved`) plus ``SR`` per
    critical-path hop (:func:`blocking_hops_1f1b_interleaved`).

    Exact at the ``F == B`` design point for
    ``SR <= blockable_sr_1f1b_interleaved(M, N, F, B)`` —
    differentially pinned over randomized (M, N, c, SR) sweeps up to
    N = 10 — and a lower bound beyond the SR premise.  Off the
    ``F == B`` point the stall pattern is irregular; the value remains
    a lower bound for N != 3 (the depth-3 anomaly can overshoot)."""
    ev = eval_1f1b_interleaved(M, N, F, B, SR, a, w, V=1)
    t = (ev.minibatch_time
         + blocking_stall_1f1b_interleaved(M, N) * min(F, B)
         + blocking_hops_1f1b_interleaved(M, N) * SR)
    return dataclasses.replace(
        ev, minibatch_time=t,
        bubble_fraction=1.0 - M * (F + B) / t if t else 0.0)


def eval_1f1b_interleaved_hetero(M: int, N: int, costs: StageCosts,
                                 a: float, w: float,
                                 V: int = 2) -> ScheduleEval:
    """Interleaved 1F1B under a per-device cost vector: the V-chunk op
    table replayed at each device's own whole-device (F, B) — every
    chunk op costs 1/V of its device's row, so a slow device stretches
    all V of its passes and the stall surfaces in the scheduled
    makespan instead of vanishing into the bottleneck collapse (the
    bug this form fixes: the explorer used to feed V > 1 candidates
    the scalar closed form even on heterogeneous clusters).  Uniform
    vectors delegate to the exact :func:`eval_1f1b_interleaved`."""
    if costs.uniform:
        return eval_1f1b_interleaved(M, N, costs.F[0], costs.B_full[0],
                                     max(costs.sr_hops, default=0.0),
                                     a, w, V=V)
    _, sim = _replay_hetero("1f1b-interleaved", M, N, costs, V=V)
    feats = tuple(float(min(M * V, (V - 1) * M + (N - i + 1))) * a
                  for i in range(1, N + 1))
    ev = _hetero_eval("1F1B-I", M, N, costs, a, w, sim, feats)
    return dataclasses.replace(
        ev, V=V, bandwidth_demand=(V * a / min(costs.F))
        if min(costs.F) > 0 else float("inf"))


def eval_1f1b_interleaved_memlean_hetero(M: int, N: int,
                                         costs: StageCosts,
                                         a: float, w: float,
                                         V: int = 2) -> ScheduleEval:
    """Memory-lean interleaved 1F1B under a per-device cost vector:
    Megatron's grouped op table replayed at per-device durations, with
    the memlean ``min(M*V, 2(N-i) + (V-1)N + 1)`` features row.  Same
    preconditions as the scalar form (``M % N == 0``); uniform vectors
    delegate to :func:`eval_1f1b_interleaved_memlean`."""
    if costs.uniform:
        return eval_1f1b_interleaved_memlean(
            M, N, costs.F[0], costs.B_full[0],
            max(costs.sr_hops, default=0.0), a, w, V=V)
    if M < N or M % N != 0:
        raise ValueError(
            f"1F1B-I-ML needs M % N == 0 (micro-batch groups of the "
            f"pipeline depth), got M={M}, N={N}")
    from repro.core.schedplan import live_activation_counts
    _, sim = _replay_hetero("1f1b-interleaved-memlean", M, N, costs, V=V)
    feats = tuple(float(c) * a for c in
                  live_activation_counts("1F1B-I-ML", M, N, V))
    ev = _hetero_eval("1F1B-I-ML", M, N, costs, a, w, sim, feats)
    return dataclasses.replace(
        ev, V=V, bandwidth_demand=(V * a / min(costs.F))
        if min(costs.F) > 0 else float("inf"))


def eval_1f1b_sno_hetero(M: int, N: int, costs: StageCosts,
                         a: float, w: float) -> ScheduleEval:
    """Synchronous no-overlap 1F1B under a per-device cost vector: the
    1F1B table replayed under the ``blocking`` comm model with each
    hop's OWN SR — every transfer occupies both endpoint devices, as on
    sync-only hardware without a comm engine.  This replaces the old
    routing bug where heterogeneous sync candidates fell through to the
    scalar closed form at the worst-hop SR (double-counting the slow
    link on every hop).  Uniform vectors delegate to the exact
    :func:`eval_1f1b_sno` closed form."""
    if costs.uniform:
        return eval_1f1b_sno(M, N, costs.F[0], costs.B_full[0],
                             max(costs.sr_hops, default=0.0), a, w)
    _, sim = _replay_hetero("1f1b", M, N, costs, comm="blocking")
    return _hetero_eval("1F1B-SNO", M, N, costs, a, w, sim,
                        _feat(1, N, a))


def eval_1f1b_so_hetero(M: int, N: int, costs: StageCosts,
                        a: float, w: float) -> ScheduleEval:
    """Synchronous overlapped 1F1B under a per-device cost vector: the
    1F1B table replayed under the ``latency`` comm model (dedicated
    comm engine, each hop paying its own SR on the wire but off the
    devices), keeping the scalar form's doubled features row (overlap
    needs the send buffer double-buffered).  Uniform vectors delegate
    to the exact :func:`eval_1f1b_so` closed form."""
    if costs.uniform:
        return eval_1f1b_so(M, N, costs.F[0], costs.B_full[0],
                            max(costs.sr_hops, default=0.0), a, w)
    _, sim = _replay_hetero("1f1b", M, N, costs, comm="latency")
    return _hetero_eval("1F1B-SO", M, N, costs, a, w, sim,
                        _feat(2, N, a))


#: Schedules with a heterogeneous vector form (the explorer feeds these
#: the partition's per-device StageCosts instead of the bottleneck
#: collapse; ZB-AUTO additionally takes ``mem_limit``, the interleaved
#: forms ``V``).
HETERO_SCHEDULES = {
    "1F1B-AS": eval_1f1b_as_hetero,
    "FBP-AS": eval_fbp_as_hetero,
    "1F1B-SNO": eval_1f1b_sno_hetero,
    "1F1B-SO": eval_1f1b_so_hetero,
    "1F1B-I": eval_1f1b_interleaved_hetero,
    "1F1B-I-ML": eval_1f1b_interleaved_memlean_hetero,
    "DAPPLE": eval_dapple_hetero,
    "ZB-H1": eval_zb_h1_hetero,
    "ZB-H2": eval_zb_h2_hetero,
    "ZB-AUTO": eval_zb_auto_hetero,
}


SCHEDULES = {
    "1F1B-AS": eval_1f1b_as,
    "FBP-AS": eval_fbp_as,
    "1F1B-SNO": eval_1f1b_sno,
    "1F1B-SO": eval_1f1b_so,
    "1F1B-I": eval_1f1b_interleaved,
    "1F1B-I-ML": eval_1f1b_interleaved_memlean,
    "DAPPLE": eval_dapple,
    "ZB-H1": eval_zb_h1,
    "ZB-H2": eval_zb_h2,
    "ZB-AUTO": eval_zb_auto,
}

ASYNC_SCHEDULES = ("1F1B-AS", "FBP-AS", "DAPPLE", "ZB-H1", "ZB-H2",
                   "ZB-AUTO", "1F1B-I", "1F1B-I-ML")
SYNC_SCHEDULES = ("1F1B-SNO", "1F1B-SO")


def schedules_for(async_capable: bool) -> tuple[str, ...]:
    """Hardware gating (paper §3.2): FPGA-like devices stream asynchronously,
    GPU-like devices must use the synchronous schedules.  The interleaved
    schedules (``1F1B-I``/``1F1B-I-ML``) rely on overlapping the
    V-times-denser boundary traffic, so they are offered to async-capable
    clusters only."""
    return ASYNC_SCHEDULES if async_capable else SYNC_SCHEDULES


# ---------------------------------------------------------------------------
# Overlapped data-parallel gradient synchronisation (the AR op kind).
#
# With pipeline x data parallelism every stage group runs its own
# gradient all-reduce over the data axis, and all of them cross the SAME
# data-axis links — DAPPLE's contention argument — so the fabric is one
# shared serial resource.  The sync-at-end baseline (the monolithic
# trailing psum) releases every device's bucket at the drain barrier:
#
#     sequential = T + sum_n ar_n,        T = max_n T_n
#
# where T_n is device n's compute end and ar_n its bucket's fabric time.
# Scheduling each AR at its own release T_n instead (the schedule-plan
# AR ops) makes the sync a single-machine schedule with release times,
# whose makespan has a closed form: sort the ends ascending,
#
#     overlapped = max_j ( T_(j) + sum_{k >= j} ar_(k) )
#
# (any work-conserving grant order gives the same value).  Since every
# release is <= T, overlapped <= sequential ALWAYS, with equality
# exactly when every device drains at the same instant (zero tail
# stagger) — any bubbled builder's staggered drain strictly wins, and
# the schedules that already erased their bubble (the zero-bubble
# family) have the least stagger left to hide the sync in.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradSyncEval:
    """Overlap-aware gradient-sync cost of one (schedule, ar) pair.
    ``exposed`` is the non-hidden sync time the mini-batch actually
    pays beyond the compute makespan; ``hidden`` is what the bubble
    absorbed versus the sync-at-end baseline."""
    name: str
    compute_makespan: float        # T: drain end without any sync
    overlapped: float              # makespan with scheduled AR ops
    sequential: float              # sync-at-end baseline: T + sum(ar)
    t_ends: tuple[float, ...]      # per-device compute end times
    ars: tuple[float, ...]         # per-device bucket fabric times
    groups: int = 1                # layer-group sub-buckets per device

    @property
    def exposed(self) -> float:
        return self.overlapped - self.compute_makespan

    @property
    def hidden(self) -> float:
        return self.sequential - self.overlapped


def grad_sync_fifo(t_ends, ars) -> float:
    """Makespan of the per-stage gradient buckets on the shared
    data-axis fabric: bucket n is released at its device's compute end
    ``t_ends[n]`` and occupies the fabric for ``ars[n]``.  Serves in
    release order (highest device first on ties, matching the tick
    lowering); the makespan is grant-order independent for any
    work-conserving order, and equals the closed form
    ``max_j (T_(j) + sum_{k>=j} ar_(k))`` over ascending-sorted ends."""
    busy = 0.0
    for end, _, a in sorted(
            (e, -n, a) for n, (e, a) in enumerate(zip(t_ends, ars))):
        busy = max(busy, end) + a
    return busy


def _grouped_releases(t_ends, ars, drains, groups: int):
    """Expand per-device buckets into ``groups`` layer-group sub-buckets.

    Splitting a bucket WITHOUT moving its release cannot reduce the
    work-conserving serial-fabric makespan (the closed form is invariant
    under same-release subdivision).  The win comes from EARLIER
    releases: the device's final drain op (duration ``drains[n]``)
    produces its layer gradients progressively in reverse-layer order,
    so layer group ``g`` of ``G`` retires at

        t(n, g) = T_n - drains[n] * (G - 1 - g) / G

    — the last group at the compute end, the first a full drain-op
    earlier — each carrying ``ar_n / G`` of the fabric time.  With
    ``groups == 1`` this is exactly the ungrouped release list."""
    if groups < 1:
        raise ValueError(f"groups must be >= 1, got {groups}")
    rel, sub = [], []
    for T_n, a, D in zip(t_ends, ars, drains):
        for g in range(groups):
            rel.append(T_n - D * (groups - 1 - g) / groups)
            sub.append(a / groups)
    return rel, sub


def _drain_durations(events, N: int) -> tuple[float, ...]:
    """Per-device duration of the LAST compute op, from a simulator
    event log (``(start, end, kind, m, vstage)``; device = vstage %
    N).  This is the op whose progressive completion the grouped
    sub-releases model."""
    dur = [0.0] * N
    last = [-1.0] * N
    for s, e, _kind, _m, vs in events:
        n = vs % N
        if e >= last[n]:
            last[n] = e
            dur[n] = e - s
    return tuple(dur)


def _uniform_drain_durs(name: str, B: float, w_frac: float,
                        N: int) -> tuple[float, ...] | None:
    """Closed-form final-drain-op durations matching
    :func:`_uniform_drain_ends`: the two-op schedules end on a full
    backward, zb-h1 tucks the final W (the ``w_frac`` share) behind the
    drain hop."""
    from repro.core.schedplan import canonical_name
    cname = canonical_name(name)
    if cname in ("gpipe", "1f1b", "dapple"):
        return (B,) * N
    if cname == "zb-h1":
        return (B * w_frac,) * N
    return None


def _uniform_drain_ends(name: str, M: int, N: int, F: float, B: float,
                        w_frac: float) -> tuple[float, ...] | None:
    """Closed-form per-device compute end times under uniform costs.

    The last op of device n in every early-backward V=1 schedule is the
    tail of micro-batch M-1's backward chain, which recrosses the
    stages at the full backward per hop (gpipe/1f1b/dapple) or at the
    input-gradient half per hop with the final W tucked right behind
    it (zb-h1), so the drain is staggered: ``T_n = T - n * stagger``.  Returns None
    for schedules whose drain has no simple uniform form (the zb-h2 /
    zb-auto banked-W tables, interleaved chunk passes) — callers fall
    back to the discrete-event replay."""
    from repro.core.schedplan import canonical_name
    cname = canonical_name(name)
    if cname in ("gpipe", "1f1b", "dapple"):
        T = (M + N - 1) * (F + B)
        return tuple(T - n * B for n in range(N))
    if cname == "zb-h1":
        Bx = B * (1.0 - w_frac)    # input-gradient half: the drain hop
        T = M * (F + B) + (N - 1) * (F + Bx)
        return tuple(T - n * Bx for n in range(N))
    return None


def eval_grad_sync(name: str, M: int, N: int, F: float, B: float,
                   ar, w_frac: float = 0.5, V: int = 1,
                   mem_limit=None, groups: int = 1) -> GradSyncEval:
    """Overlap-aware closed form for the exposed gradient-sync time of
    a schedule under uniform per-device costs.  ``ar`` is the
    per-device bucket fabric time (scalar or length-N).  Uses the
    analytic drain ends where the uniform form exists
    (:func:`_uniform_drain_ends`) and the discrete-event replay
    otherwise; the two agree for every builder (differentially
    tested).  ``groups > 1`` splits each device's bucket into
    per-layer-group sub-buckets released progressively through the
    final drain op (:func:`_grouped_releases`) — exposed sync is
    non-increasing in ``groups``."""
    if groups < 1:
        raise ValueError(f"groups must be >= 1, got {groups}")
    ars = tuple([float(ar)] * N if isinstance(ar, (int, float))
                else [float(a) for a in ar])
    if len(ars) != N:
        raise ValueError(f"ar needs one entry per device ({N}), "
                         f"got {len(ars)}")
    ends = _uniform_drain_ends(name, M, N, F, B, w_frac) if V == 1 else None
    drains = _uniform_drain_durs(name, B, w_frac, N) if ends else None
    if ends is None:
        from repro.core.schedplan import build_schedule
        from repro.core.simulator import simulate
        plan = build_schedule(name, M, N, V, mem_limit=mem_limit)
        sim = simulate(plan, M, N, F, B, 0.0, V=V, w_frac=w_frac)
        ends = tuple(sim.t_end)
        drains = _drain_durations(sim.events, N)
    rel, sub = _grouped_releases(ends, ars, drains, groups)
    T = max(ends)
    return GradSyncEval(
        name=name, compute_makespan=T,
        overlapped=grad_sync_fifo(rel, sub),
        sequential=T + sum(ars), t_ends=ends, ars=ars, groups=groups)


def eval_grad_sync_costs(name: str, M: int, N: int, costs: StageCosts,
                         ar, mem_limit=None, groups: int = 1) -> GradSyncEval:
    """Heterogeneous form of :func:`eval_grad_sync`: per-device drain
    ends from the cost-shaped replay (:func:`_replay_hetero`), so the
    exposed sync the explorer ranks by matches what the simulator pins
    on skewed clusters.  ``groups`` as in :func:`eval_grad_sync`, with
    the drain-op durations read off the replay's event log."""
    if groups < 1:
        raise ValueError(f"groups must be >= 1, got {groups}")
    ars = tuple([float(ar)] * N if isinstance(ar, (int, float))
                else [float(a) for a in ar])
    if len(ars) != N:
        raise ValueError(f"ar needs one entry per device ({N}), "
                         f"got {len(ars)}")
    _, sim = _replay_hetero(canonical_replay_name(name), M, N, costs,
                            mem_limit=mem_limit)
    ends = tuple(sim.t_end)
    rel, sub = _grouped_releases(ends, ars, _drain_durations(sim.events, N),
                                 groups)
    T = max(ends)
    return GradSyncEval(
        name=name, compute_makespan=T,
        overlapped=grad_sync_fifo(rel, sub),
        sequential=T + sum(ars), t_ends=ends, ars=ars, groups=groups)


def eval_grad_sync_2bw(name: str, M: int, N: int, F: float, B: float,
                       ar, w_frac: float = 0.5, V: int = 1,
                       mem_limit=None) -> GradSyncEval:
    """Steady-state sync cost under PipeDream-2BW double-buffered
    weights: step k's gradient all-reduce is consumed only at step
    k+1's weight apply, so the collective has a full step of slack and
    is never on the critical path — ``overlapped == compute_makespan``
    (exposed == 0) whenever the fabric can drain ``sum(ar)`` within one
    step, i.e. ``sum(ar) <= compute_makespan``.  Beyond that the
    fabric itself is the bottleneck and the step pays the excess."""
    sync = eval_grad_sync(name, M, N, F, B, ar, w_frac=w_frac, V=V,
                          mem_limit=mem_limit)
    T = sync.compute_makespan
    total_ar = sum(sync.ars)
    return GradSyncEval(
        name=name, compute_makespan=T,
        overlapped=max(T, total_ar),
        sequential=sync.sequential, t_ends=sync.t_ends, ars=sync.ars)


def canonical_replay_name(name: str) -> str:
    """Builder name for a schedule-table name (the grad-sync evals
    accept both the Table-1 names and the canonical builder names)."""
    from repro.core.schedplan import canonical_name
    return canonical_name(name)
