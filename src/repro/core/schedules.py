"""Closed-form pipeline-schedule cost models — paper Tables 1 and 2.

Four schedules:

* ``1F1B-AS`` — async (FPGA-style) one-forward-one-backward.
* ``FBP-AS``  — async, FP and BP computed in parallel on each accelerator
  (FPDeep).  Same makespan, double activation memory, lower bandwidth demand.
* ``1F1B-SNO`` — synchronous, communication NOT overlapped with compute.
* ``1F1B-SO``  — synchronous, overlapped via doubled warm-up micro-batches
  (the paper's contribution). Double activation memory vs SNO.

Symbols (paper):  M = micro-batches per mini-batch, N = pipeline stages,
F/B = per-micro-batch FP/BP compute time of one (balanced) stage,
SR = send/receive time of one stage boundary, a = activation bytes of one
stage boundary (per micro-batch), w = weight bytes of one stage,
i = stage index 1..N.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ScheduleEval:
    name: str
    minibatch_time: float
    bubble_fraction: float
    features_memory: tuple[float, ...]   # per stage i=1..N
    weights_memory: float                # per stage (2w: weights + grads)
    bandwidth_demand: float              # bytes/s needed to fully overlap


def _feat(mult: int, N: int, a: float) -> tuple[float, ...]:
    return tuple(float(mult * (N - i + 1)) * a for i in range(1, N + 1))


def eval_1f1b_as(M: int, N: int, F: float, B: float, SR: float,
                 a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B)
    return ScheduleEval(
        name="1F1B-AS", minibatch_time=t,
        bubble_fraction=(N - 1) / (M + N - 1),
        features_memory=_feat(1, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_fbp_as(M: int, N: int, F: float, B: float, SR: float,
                a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B)
    return ScheduleEval(
        name="FBP-AS", minibatch_time=t,
        bubble_fraction=(N - 1) / (M + N - 1),
        features_memory=_feat(2, N, a), weights_memory=2 * w,
        bandwidth_demand=(2 * a / (F + B)) if F + B > 0 else float("inf"))


def eval_1f1b_sno(M: int, N: int, F: float, B: float, SR: float,
                  a: float, w: float) -> ScheduleEval:
    extra = (N + M - 2 - math.ceil((M - 1) / N)) * 2 * SR
    t = (M + N - 1) * (F + B) + extra
    bubble = ((N - 1) * (F + B + 2 * SR)
              + (M - 1 - math.ceil((M - 1) / N)) * 2 * SR) / t if t else 0.0
    return ScheduleEval(
        name="1F1B-SNO", minibatch_time=t, bubble_fraction=bubble,
        features_memory=_feat(1, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


def eval_1f1b_so(M: int, N: int, F: float, B: float, SR: float,
                 a: float, w: float) -> ScheduleEval:
    t = (M + N - 1) * (F + B) + (N - 1) * 2 * SR
    bubble = (N - 1) * (F + B + 2 * SR) / t if t else 0.0
    return ScheduleEval(
        name="1F1B-SO", minibatch_time=t, bubble_fraction=bubble,
        features_memory=_feat(2, N, a), weights_memory=2 * w,
        bandwidth_demand=(a / F) if F > 0 else float("inf"))


SCHEDULES = {
    "1F1B-AS": eval_1f1b_as,
    "FBP-AS": eval_fbp_as,
    "1F1B-SNO": eval_1f1b_sno,
    "1F1B-SO": eval_1f1b_so,
}

ASYNC_SCHEDULES = ("1F1B-AS", "FBP-AS")
SYNC_SCHEDULES = ("1F1B-SNO", "1F1B-SO")


def schedules_for(async_capable: bool) -> tuple[str, ...]:
    """Hardware gating (paper §3.2): FPGA-like devices stream asynchronously,
    GPU-like devices must use the synchronous schedules."""
    return ASYNC_SCHEDULES if async_capable else SYNC_SCHEDULES
