from repro.checkpoint.ckpt import (CheckpointMismatch, checkpoint_meta,
                                   checkpoint_step, restore_checkpoint,
                                   save_checkpoint)
from repro.checkpoint.reshard import (layout_dict, plan_from_layout,
                                      reshard_checkpoint, reshard_tree)
