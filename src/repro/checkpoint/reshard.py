"""Plan-to-plan checkpoint resharding — the elastic layer's relayout step.

A training job saves ``{params, opt, step}`` with every layer-stacked leaf
in the layout of its :class:`~repro.pipeline.stage.StagePlan`:
``[S, Lps, ...]`` for a contiguous plan, ``[S, V, Lc, ...]`` for an
interleaved one.  When the fleet shrinks, grows, or re-skews, the next
incarnation of the job runs under a *different* ``(N, V)`` layout — this
module repartitions a saved checkpoint between any two such layouts so the
job resumes on the new fleet with bit-identical real-layer weights and
optimizer moments.

Mechanics: every leaf under a ``layers`` subtree (params AND the
optimizer's per-parameter moments, which mirror the params structure) is
unstacked to the global layer order, trimmed to the real layers, re-padded
and re-stacked for the target plan via the existing
:func:`repro.pipeline.stage.restack_layers` machinery.  The relayout is a
pure gather: real-layer values are moved bit-for-bit; padded slots (which
are inactive — pass-through forward, zero gradient) are re-seeded by
repeating the last real layer.  Non-layer leaves (embed / head /
final_norm, scalar step counters) pass through untouched, so the two
layouts must agree on everything outside the stage stacking (in
particular the tensor degree's vocab padding).

Two entry points:

- :func:`reshard_tree` — in-memory pytree relayout (the ``--resume`` path
  uses it when the checkpoint's recorded layout differs from the target).
- :func:`reshard_checkpoint` — file-to-file relayout on the host, no
  devices needed (the operator-side path: repartition a dead 8-device
  job's checkpoint for the 4 skewed survivors before relaunching).
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np
import jax

from repro.checkpoint.ckpt import checkpoint_meta, CheckpointMismatch
from repro.pipeline.stage import StagePlan, restack_layers


def layout_dict(plan: StagePlan, n_layers: int) -> dict:
    """Msgpack-able layout descriptor stored in the checkpoint's ``extra``
    meta (``extra["layout"]``) so resume can detect a layout change."""
    return dict(stages=plan.n_stages, tensor=plan.tensor,
                virtual=plan.virtual,
                layers_per_stage=plan.layers_per_stage,
                n_layers_padded=plan.n_layers_padded,
                n_layers=int(n_layers))


def plan_from_layout(layout: dict) -> StagePlan:
    return StagePlan(n_stages=layout["stages"], tensor=layout["tensor"],
                     layers_per_stage=layout["layers_per_stage"],
                     n_layers_padded=layout["n_layers_padded"],
                     virtual=layout.get("virtual", 1))


def _lead_shape(plan: StagePlan) -> tuple[int, ...]:
    if plan.virtual == 1:
        return (plan.n_stages, plan.layers_per_stage)
    return (plan.n_stages, plan.virtual, plan.layers_per_stage)


def _is_layer_path(names) -> bool:
    """True for leaves living under a ``layers`` subtree (the stacked
    per-layer parameters and their optimizer-moment mirrors)."""
    return "layers" in names[:-1]


def _check_lead(name: str, shape, plan: StagePlan) -> None:
    lead = _lead_shape(plan)
    if tuple(shape[:len(lead)]) != lead:
        raise CheckpointMismatch(
            f"layer leaf {name!r} has shape {tuple(shape)}, which does not "
            f"carry the source plan's stacking {lead} "
            f"(S={plan.n_stages}, V={plan.virtual}, "
            f"Lc={plan.layers_per_stage})")


def reshard_tree(tree: Any, plan_from: StagePlan, plan_to: StagePlan,
                 n_layers: int) -> Any:
    """Relayout every layer-stacked leaf of ``tree`` (a ``{params, opt}``
    state or any subtree of one) from ``plan_from``'s chunk stacking to
    ``plan_to``'s.  Real-layer values are preserved bit-for-bit."""
    if n_layers > plan_to.n_layers_padded:
        raise CheckpointMismatch(
            f"target plan holds {plan_to.n_layers_padded} padded layers "
            f"< {n_layers} real layers")

    def leaf(path, a):
        names = [getattr(p, "key", getattr(p, "name", None)) for p in path]
        if not _is_layer_path(names):
            return a
        _check_lead("/".join(str(n) for n in names), a.shape, plan_from)
        return restack_layers(a, plan_from, plan_to, n_layers)

    return jax.tree_util.tree_map_with_path(leaf, tree)


def reshard_checkpoint(src: str, dst: str, plan_to: StagePlan,
                       plan_from: Optional[StagePlan] = None,
                       n_layers: Optional[int] = None) -> dict:
    """File-to-file resharding: read the checkpoint at ``src`` (npz +
    meta), restack every ``layers`` leaf from ``plan_from`` to
    ``plan_to``, and write ``dst``.  ``plan_from``/``n_layers`` default to
    the layout recorded in the source's meta (``extra["layout"]``).

    Dtypes, the step counter, and all non-layer leaves are preserved
    exactly; the written meta records ``plan_to``'s layout.  Returns the
    new layout dict.  Runs entirely on the host — no accelerator (or any
    particular device count) is needed, so a checkpoint from a dead
    8-device job can be repartitioned anywhere before the 4-device
    relaunch."""
    import os

    import msgpack

    meta = checkpoint_meta(src)
    layout = (meta.get("extra") or {}).get("layout")
    if plan_from is None or n_layers is None:
        if layout is None:
            raise CheckpointMismatch(
                f"checkpoint {src!r} records no layout in its meta; pass "
                f"plan_from and n_layers explicitly")
        plan_from = plan_from or plan_from_layout(layout)
        n_layers = n_layers if n_layers is not None else layout["n_layers"]
    if plan_from.tensor != plan_to.tensor:
        raise CheckpointMismatch(
            f"tensor degree change ({plan_from.tensor} -> {plan_to.tensor}) "
            f"would re-pad the vocab; reshard only moves stage boundaries "
            f"and virtual chunks")

    os.makedirs(os.path.dirname(dst) or ".", exist_ok=True)
    data = np.load(src + ".npz")
    out = {}
    for key in data.files:
        a = data[key]
        if _is_layer_path(key.split("/")):
            _check_lead(key, a.shape, plan_from)
            a = np.asarray(restack_layers(a, plan_from, plan_to, n_layers))
        out[key] = a
    np.savez(dst + ".npz", **out)
    new_layout = layout_dict(plan_to, n_layers)
    meta = dict(meta)
    extra = dict(meta.get("extra") or {})
    extra["layout"] = new_layout
    meta["extra"] = extra
    with open(dst + ".meta", "wb") as f:
        f.write(msgpack.packb(meta))
    return new_layout
