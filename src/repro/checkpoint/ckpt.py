"""Checkpointing: pytree <-> npz (+ msgpack metadata), sharding-aware.

Arrays are gathered to host (fully replicated view) before writing; restore
re-places each leaf with the provided sharding tree when given.

Restore semantics are driven by the checkpoint's own ``.meta`` sidecar, not
by the caller's ``like`` tree: each leaf is cast back to the dtype it was
*saved* with (``meta["dtypes"]`` — bf16 survives the f32 npz encoding), and
key-set or shape disagreements between the file and ``like`` raise a
:class:`CheckpointMismatch` naming the offending keys instead of a bare
``KeyError``.  ``like`` supplies only structure and expected shapes; its
leaves may be ``jax.ShapeDtypeStruct``s.

The ``.meta`` sidecar also carries an open ``extra`` dict (the elastic
layer stores the stage layout there — see
:mod:`repro.checkpoint.reshard`).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import msgpack
import numpy as np
import jax


class CheckpointMismatch(ValueError):
    """The checkpoint on disk does not match the requested ``like`` tree
    (missing/unexpected keys or shape disagreement)."""


def _is_namedtuple(tree) -> bool:
    return isinstance(tree, tuple) and hasattr(type(tree), "_fields")


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    extra: Optional[dict] = None) -> None:
    """Write ``tree`` to ``path.npz`` + ``path.meta``.  ``extra`` is an
    arbitrary msgpack-able dict stored in the sidecar (layout descriptors
    etc.; read back with :func:`checkpoint_meta`)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.kind == "V":          # bfloat16 has no native npz encoding
            a = a.astype(np.float32)     # dtypes[k] still says 'bfloat16'
        arrays[k] = a
    np.savez(path + ".npz", **arrays)
    meta = dict(step=step, keys=sorted(arrays), dtypes=dtypes,
                extra=extra or {})
    with open(path + ".meta", "wb") as f:
        f.write(msgpack.packb(meta))


def _load_meta(path: str) -> dict:
    with open(path + ".meta", "rb") as f:
        return msgpack.unpackb(f.read())


def restore_checkpoint(path: str, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    """Rebuild the pytree saved at ``path`` into the structure of ``like``.

    Leaves come back in their SAVED dtype (``meta["dtypes"]``), not the
    ``like`` leaf's — a bf16 checkpoint restores as bf16 even when the
    caller hands an f32 skeleton.  Sequences are rebuilt with their own
    type; NamedTuple nodes (optax-style opt states) are splatted through
    their constructor.  ``shardings`` (a matching pytree of shardings)
    triggers a per-leaf ``device_put``.
    """
    data = np.load(path + ".npz")
    meta = _load_meta(path)
    dtypes = meta.get("dtypes", {})
    flat_like = _flatten(like)

    saved_keys = set(data.files)
    like_keys = set(flat_like)
    if saved_keys != like_keys:
        missing = sorted(like_keys - saved_keys)
        unexpected = sorted(saved_keys - like_keys)
        raise CheckpointMismatch(
            f"checkpoint {path!r} does not match the requested tree: "
            + (f"missing keys {missing}" if missing else "")
            + (" ; " if missing and unexpected else "")
            + (f"unexpected keys {unexpected}" if unexpected else ""))
    bad_shapes = []
    for k in sorted(like_keys):
        want = tuple(getattr(flat_like[k], "shape", np.shape(flat_like[k])))
        got = data[k].shape
        if want != got:
            bad_shapes.append(f"{k}: saved {got} != expected {want}")
    if bad_shapes:
        raise CheckpointMismatch(
            f"checkpoint {path!r} shape mismatch (reshard it first? see "
            f"repro.checkpoint.reshard): " + " ; ".join(bad_shapes))

    flat_sh = _flatten(shardings) if shardings is not None else {}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            if _is_namedtuple(tree):
                return type(tree)(*vals)
            return type(tree)(vals)
        key = prefix[:-1]
        arr = data[key]
        dt = dtypes.get(key)
        if dt is not None and str(arr.dtype) != dt:
            arr = arr.astype(dt)      # ml_dtypes makes 'bfloat16' a valid name
        arr = jax.numpy.asarray(arr)
        sh = flat_sh.get(key)
        return jax.device_put(arr, sh) if sh is not None else arr

    return rebuild(like)


def checkpoint_meta(path: str) -> dict:
    """Full ``.meta`` sidecar: ``step``, ``keys``, ``dtypes``, ``extra``."""
    return _load_meta(path)


def checkpoint_step(path: str) -> int:
    return _load_meta(path)["step"]
