"""Checkpointing: pytree <-> npz (+ msgpack metadata), sharding-aware.

Arrays are gathered to host (fully replicated view) before writing; restore
re-places each leaf with the provided sharding tree when given.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import msgpack
import numpy as np
import jax


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        a = np.asarray(jax.device_get(v))
        dtypes[k] = str(a.dtype)
        if a.dtype.kind == "V":          # bfloat16 has no numpy equivalent
            a = a.astype(np.float32)
        arrays[k] = a
    np.savez(path + ".npz", **arrays)
    meta = dict(step=step, keys=sorted(arrays), dtypes=dtypes)
    with open(path + ".meta", "wb") as f:
        f.write(msgpack.packb(meta))


def restore_checkpoint(path: str, like: Any,
                       shardings: Optional[Any] = None) -> Any:
    data = np.load(path + ".npz")
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], f"{prefix}{k}/") for k in tree}
        if isinstance(tree, (list, tuple)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(tree)]
            return type(tree)(vals)
        key = prefix[:-1]
        arr = jax.numpy.asarray(data[key]).astype(flat_like[key].dtype)
        sh = flat_sh.get(key)
        return jax.device_put(arr, sh) if sh is not None else arr

    return rebuild(like)


def checkpoint_step(path: str) -> int:
    with open(path + ".meta", "rb") as f:
        return msgpack.unpackb(f.read())["step"]
