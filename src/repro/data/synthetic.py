"""Deterministic synthetic LM data pipeline.

Sequences follow a fixed random bigram chain over the vocabulary with
tunable noise, so a model that learns bigram statistics drives the loss
well below the unigram entropy — good enough to validate end-to-end
training dynamics without shipping a corpus.  Batches are a pure function
of (seed, step), so every data shard / restart is reproducible.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    branch: int = 4          # successors per token in the bigram chain

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.table = rng.integers(0, self.vocab,
                                  size=(self.vocab, self.branch),
                                  dtype=np.int64)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, T = self.global_batch, self.seq_len + 1
        toks = np.empty((B, T), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=B)
        branch = rng.integers(0, self.branch, size=(B, T))
        noise_mask = rng.random((B, T)) < self.noise
        noise_tok = rng.integers(0, self.vocab, size=(B, T))
        for t in range(1, T):
            nxt = self.table[toks[:, t - 1], branch[:, t]]
            toks[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return dict(tokens=jnp.asarray(toks[:, :-1], jnp.int32),
                    labels=jnp.asarray(toks[:, 1:], jnp.int32))


def make_batch_specs(mesh, batch_axes=("data",)):
    return dict(tokens=NamedSharding(mesh, P(batch_axes, None)),
                labels=NamedSharding(mesh, P(batch_axes, None)))


def shard_batch(batch: dict, shardings: dict) -> dict:
    return {k: jax.device_put(v, shardings[k]) if k in shardings else v
            for k, v in batch.items()}
