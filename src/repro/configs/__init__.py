"""Config registry: ``get_config(arch_id)`` -> ArchConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                LONG_CONTEXT_OK)

_ARCHS = (
    "minicpm3_4b", "mamba2_2p7b", "hymba_1p5b", "gemma3_1b", "llama3p2_1b",
    "whisper_base", "qwen2_vl_7b", "qwen3_1p7b", "deepseek_v3_671b",
    "deepseek_v2_lite_16b",
)

_BY_ID: dict[str, ArchConfig] = {}


def _load():
    if _BY_ID:
        return
    for mod_name in _ARCHS:
        mod = importlib.import_module(f"repro.configs.{mod_name}")
        cfg: ArchConfig = mod.CONFIG
        _BY_ID[cfg.arch_id] = cfg


def get_config(arch_id: str) -> ArchConfig:
    _load()
    return _BY_ID[arch_id]


def all_arch_ids() -> list[str]:
    _load()
    return sorted(_BY_ID)
