"""Architecture configuration schema.

One ``ArchConfig`` fully describes a model family member: the transformer /
SSM backbone, attention flavour (GQA / MLA / sliding-window mix / hybrid),
FFN flavour (dense / MoE), modality frontend stubs, and the BaPipe pipeline
defaults (stage x tensor factorisation of the mesh "model" axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_ff_expert: int
    first_k_dense: int = 0           # leading layers that stay dense
    router_aux_weight: float = 0.001
    capacity_factor: float = 1.25
    ep_data: bool = False            # shard experts over the data axis too
                                     # (tokens travel by all_to_all)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int                 # 0 => direct q projection
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 256

    def n_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads
    source: str = ""                 # citation

    # attention flavour -----------------------------------------------------
    attn_kind: str = "gqa"           # gqa | mla | none (pure ssm)
    mla: Optional[MLAConfig] = None
    qk_norm: bool = False
    # sliding-window pattern: window>0 and global_every=k => every k-th layer
    # (1-indexed) is global, the rest use a local window.
    window: int = 0
    global_every: int = 0
    global_layers: Optional[tuple[int, ...]] = None   # explicit global set
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3: different theta on global layers
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # FFN / MoE --------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    act: str = "silu"

    # SSM / hybrid ------------------------------------------------------------
    ssm: Optional[SSMConfig] = None  # set for family in {ssm, hybrid}

    # encoder-decoder (audio) -------------------------------------------------
    n_enc_layers: int = 0            # >0 => enc-dec; n_layers counts TOTAL
    frontend: Optional[str] = None   # audio | vision (STUB embeddings)

    # extras -------------------------------------------------------------------
    mtp: bool = False                # deepseek-v3 multi-token prediction head
    tie_embeddings: bool = True
    norm_eps: float = 1e-6

    # BaPipe pipeline defaults (stage * tensor == mesh "model" axis size) ------
    stages: int = 16
    tensor: int = 1
    virtual: int = 1                 # 1F1B-I virtual stages (chunks) per device
    schedule: str = "auto"           # runtime op order (schedplan name):
                                     # auto | gpipe | 1f1b | dapple | zb-h1 |
                                     # zb-h2 | zb-auto | 1f1b-interleaved |
                                     # 1f1b-interleaved-memlean
    mem_limit: int = 0               # zb-auto peak-live cap (resident
                                     # micro-batch residuals per device);
                                     # 0 = unbounded (fully bubble-free)
    runtime: str = "ticks"           # training executor: "ticks" (globally
                                     # synchronous tick grid, rings shift
                                     # every tick) | "stream" (compiled
                                     # instruction streams, ring collectives
                                     # only at scheduled SEND slots)
    fsdp: bool = False               # shard stage weights over "data" axis too
    profile_w_frac: str = "analytic" # backward B/W split source for the
                                     # profiler: "analytic" (weight-matmul
                                     # flop share) | "measured" (real vjp
                                     # timings of one representative layer,
                                     # falling back to analytic)

    # ----------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers - self.n_enc_layers

    def is_global_layer(self, i: int) -> bool:
        """Layer i (0-indexed) uses global attention?"""
        if self.window <= 0:
            return True
        if self.global_layers is not None:
            return i in self.global_layers
        if self.global_every <= 0:
            return False
        return (i + 1) % self.global_every == 0

    def padded_vocab(self, tp: int) -> int:
        """Vocab rounded up so the embedding shards evenly over ``tp``."""
        mult = tp * 128
        return (self.vocab + mult - 1) // mult * mult

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        from repro.core.profiler import profile_arch   # local to avoid cycle
        prof = profile_arch(self)
        body = sum(l.bytes_weights for l in prof.layers) // prof.bytes_per_param
        emb = self.vocab * self.d_model
        head = 0 if self.tie_embeddings else self.vocab * self.d_model
        return body + emb + head

    def reduced(self, *, n_layers: int = 2, d_model: int = 256,
                seq: int = 64) -> "ArchConfig":
        """Smoke-test variant: same family/flavours, tiny dims."""
        n_heads = max(2, min(4, self.n_heads))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        hd = max(16, d_model // n_heads)
        changes: dict = dict(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, head_dim=hd, d_ff=2 * d_model,
            vocab=min(self.vocab, 1024), stages=1, tensor=1, virtual=1,
            schedule="auto", mem_limit=0, fsdp=False,
        )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=min(self.mla.q_lora_rank, d_model // 2) if self.mla.q_lora_rank else 0,
                kv_lora_rank=min(self.mla.kv_lora_rank, d_model // 4),
                qk_nope_dim=hd, qk_rope_dim=max(8, hd // 2),
                v_head_dim=hd)
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_routed=4, n_shared=min(self.moe.n_shared, 1),
                top_k=2, d_ff_expert=d_model, first_k_dense=min(self.moe.first_k_dense, 1))
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=16)
        if self.window:
            changes["window"] = min(self.window, seq // 2)
            changes["global_every"] = min(self.global_every, n_layers) or 0
            if self.global_layers is not None:
                changes["global_layers"] = (0,)
        if self.mrope_sections is not None:
            half = hd // 2
            q = half // 4
            changes["mrope_sections"] = (half - 2 * q, q, q)
        if self.n_enc_layers:
            changes["n_enc_layers"] = n_layers // 2
            changes["n_layers"] = n_layers if n_layers % 2 == 0 else n_layers + 1
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k at baseline (sub-quadratic / windowed decode).
LONG_CONTEXT_OK = {"mamba2-2.7b", "hymba-1.5b", "gemma3-1b"}
