"""Mamba2-2.7B [arXiv:2405.21060] — pure SSM (SSD), attention-free.

64L d_model=2560, vocab=50280, ssm_state=128.  No attention, no FFN
(mamba2 blocks only, d_ff=0 per the assignment).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b", family="ssm", source="arXiv:2405.21060",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280,
    attn_kind="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=256),
    rope_theta=0.0, tie_embeddings=True,
    stages=16, tensor=1,
)
