"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3 dense GQA.

16L d_model=2048 32H (kv=8) d_ff=8192 vocab=128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama3.2-1b", family="dense", source="hf:meta-llama/Llama-3.2-1B",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab=128256, head_dim=64,
    attn_kind="gqa",
    rope_theta=500_000.0,
    stages=8, tensor=2,    # 2 layers/stage
)
