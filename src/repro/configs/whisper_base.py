"""Whisper-base [arXiv:2212.04356] — encoder-decoder audio backbone.

6L total (3 enc + 3 dec) d_model=512 8H d_ff=2048 vocab=51865.
The mel-spectrogram + conv frontend is a STUB: input_specs() provides
precomputed frame embeddings [B, S_frames, d].  rope_theta=0 selects
sinusoidal absolute positions (whisper uses absolute embeddings).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-base", family="audio", source="arXiv:2212.04356",
    n_layers=6, n_enc_layers=3, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, head_dim=64,
    attn_kind="gqa",
    rope_theta=0.0, act="gelu",
    frontend="audio", tie_embeddings=True,
    stages=2, tensor=8,
)
