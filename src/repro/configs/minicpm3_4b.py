"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense, MLA attention.

62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448.  MLA dims from the
model card: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v=64.
"""
from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    arch_id="minicpm3-4b", family="dense", source="hf:openbmb/MiniCPM3-4B",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, head_dim=64,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64),
    rope_theta=10_000.0,
    stages=16, tensor=1,
)
