"""Hymba-1.5B [arXiv:2411.13676] — hybrid: parallel attention + mamba heads.

32L d_model=1600 25H (kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention everywhere except three full-attention layers
(first, middle, last — per the paper).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    arch_id="hymba-1.5b", family="hybrid", source="arXiv:2411.13676",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64,
    attn_kind="gqa",
    window=1024, global_layers=(0, 15, 31),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4, chunk=256),
    rope_theta=10_000.0,
    stages=16, tensor=1,
)
