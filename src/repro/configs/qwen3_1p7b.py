"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — dense GQA with qk_norm.

28L d_model=2048 16H (kv=8) d_ff=6144 vocab=151936, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-1.7b", family="dense", source="hf:Qwen/Qwen3-8B",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=6144,
    vocab=151936, head_dim=128,
    attn_kind="gqa", qk_norm=True,
    rope_theta=1_000_000.0,
    stages=4, tensor=4,    # 7 layers/stage
)
