"""Qwen2-VL-7B [arXiv:2409.12191] — VLM language backbone with M-RoPE.

28L d_model=3584 28H (kv=4) d_ff=18944 vocab=152064.  The ViT vision
encoder + projector is a STUB: input_specs() provides interleaved
text/patch embeddings plus the 3-axis (t,h,w) M-RoPE position ids.
head_dim=128 -> M-RoPE sections (16,24,24) over the 64 half-dims.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-7b", family="vlm", source="arXiv:2409.12191",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab=152064, head_dim=128,
    attn_kind="gqa",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision", tie_embeddings=False,
    stages=4, tensor=4,    # 7 layers/stage
)
