"""DeepSeek-V2-Lite-16B [arXiv:2405.04434] — MoE + MLA.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.
MoE: 2 shared + 64 routed, top-6 (V2-Lite model card; the assignment
line's "160 routed" belongs to full V2 — see DESIGN.md §5).
MLA: kv_lora=512, no q compression, qk_nope=128, qk_rope=64, v=128.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-lite-16b", family="moe", source="arXiv:2405.04434",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_ff_expert=1408,
                  first_k_dense=0),
    rope_theta=10_000.0, tie_embeddings=False,
    stages=4, tensor=4,   # 7 layers/stage (1 pad), 16 experts/device
)
