"""Gemma3-1B [hf:google/gemma-3-1b-pt] — dense, 5:1 local:global, 128k rope.

26L d_model=1152 4H (kv=1) d_ff=6912 vocab=262144, head_dim=256,
sliding window 512 on local layers, every 6th layer global with
rope_theta 1M (local layers use 10k).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma3-1b", family="dense", source="hf:google/gemma-3-1b-pt",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, head_dim=256,
    attn_kind="gqa", qk_norm=True,
    window=512, global_every=6,
    rope_theta=10_000.0, rope_theta_global=1_000_000.0,
    act="gelu",
    stages=4, tensor=4,    # 7 layers/stage (2 pad); kv=1 replicated over tensor
)
