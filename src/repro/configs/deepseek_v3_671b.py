"""DeepSeek-V3-671B [arXiv:2412.19437] — MoE + MLA (+ MTP).

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
MoE: 1 shared + 256 routed, top-8.  MLA: q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v=128.

Deviation note (DESIGN.md §5): the HF model keeps the first 3 layers as
wide dense FFN; the assignment specifies d_ff=2048 uniformly, so the
pipeline config uses first_k_dense=0 (all-MoE trunk).  MTP is available as
an optional extra head in the training driver.
"""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=2048,
    vocab=129280, head_dim=128,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_ff_expert=2048,
                  first_k_dense=0, ep_data=True),
    rope_theta=10_000.0, mtp=True, tie_embeddings=False,
    stages=8, tensor=2, fsdp=True,   # experts 256/(16 data x 2 tensor)=8 per chip
)
