"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive: full [T,S] score materialisation for
attention, full per-chunk tensors for SSD.  Tests sweep shapes/dtypes and
assert the kernels (interpret=True on CPU) match these to tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool = True,
                        kv_len=None) -> jax.Array:
    """q: [BH, T, D], k/v: [BH, S, D].  f32 accumulation."""
    T, S = q.shape[1], k.shape[1]
    logits = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= jnp.arange(S)[None, :] <= jnp.arange(T)[:, None]
    if kv_len is not None:
        mask &= jnp.arange(S)[None, :] < kv_len
    logits = jnp.where(mask[None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                 Cm: jax.Array, *, init_state=None):
    """Sequential SSD recurrence, the exact semantics both the chunked jnp
    path and the Pallas kernel must reproduce.

    x: [B,T,H,P], dt: [B,T,H], A: [H] (negative), Bm/Cm: [B,T,N].
    Returns (y: [B,T,H,P], final_state: [B,H,P,N])."""
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    st = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(st, inp):
        xt, dtt, Bt, Ct = inp                 # [B,H,P], [B,H], [B,N]
        dA = jnp.exp(dtt * A[None, :])        # [B,H]
        st = st * dA[:, :, None, None] + jnp.einsum(
            "bh,bhp,bn->bhpn", dtt, xt.astype(jnp.float32),
            Bt.astype(jnp.float32))
        y = jnp.einsum("bn,bhpn->bhp", Ct.astype(jnp.float32), st)
        return st, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    st, ys = jax.lax.scan(step, st, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), st
