"""Jitted public wrappers for the Pallas kernels.

``use_pallas`` flows from model configs; on this CPU container kernels run
in interpret mode (the TPU lowering is exercised on real hardware).  The
wrappers adapt the model-layer layouts ([B,T,H,D] GQA attention, SSD block
tensors) to the kernels' flattened layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("scale", "causal", "interpret"))
def mha_flash(q: jax.Array, k: jax.Array, v: jax.Array, *, scale: float,
              causal: bool = True, interpret: bool = True) -> jax.Array:
    """[B,T,Hq,D] x [B,S,Hkv,D] GQA flash attention (kv broadcast to q
    heads, batch*heads flattened for the kernel)."""
    B, T, Hq, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    kf = jnp.repeat(k, rep, axis=2) if rep > 1 else k
    vf = jnp.repeat(v, rep, axis=2) if rep > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    kf = kf.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    vf = vf.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    out = flash_attention(qf, kf, vf, scale=scale, causal=causal,
                          interpret=interpret)
    return out.reshape(B, Hq, T, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, *, chunk: int = 256,
        interpret: bool = True) -> jax.Array:
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
