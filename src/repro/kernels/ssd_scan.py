"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

TPU-native formulation of the state-space duality algorithm: the grid is
(batch, head-blocks, chunks) with the chunk dimension innermost, so the
[hb, P, N] recurrent state lives in VMEM scratch across the sequential
chunk sweep.  Each grid step does three MXU-friendly matmul groups
(intra-chunk C·Bᵀ scores, carried-state readout, chunk-state update) —
the same decomposition the paper uses to turn a scan into matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    x = x_ref[0].astype(jnp.float32)          # [c, hb, P]
    dt = dt_ref[0].astype(jnp.float32)        # [c, hb]
    A = a_ref[...].astype(jnp.float32)        # [hb]
    Bm = b_ref[0].astype(jnp.float32)         # [c, N]
    Cm = c_ref[0].astype(jnp.float32)         # [c, N]

    dA = dt * A[None, :]                      # [c, hb]  (negative)
    dA_cum = jnp.cumsum(dA, axis=0)
    # intra-chunk: masked decay kernel, then  (C B^T * L * dt) @ x
    seg = dA_cum[:, None, :] - dA_cum[None, :, :]        # [c, c, hb]
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    Lmat = jnp.exp(jnp.where(causal[:, :, None], seg, -1e30))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [c, c]
    w = scores[:, :, None] * Lmat * dt[None, :, :]       # [c, s, hb]
    y_diag = jnp.einsum("csh,shp->chp", w, x)
    # carried-state readout: y_off[c,h,p] = sum_n C[c,n] e^{dA_cum} st[h,p,n]
    state = st_ref[...]                                   # [hb, P, N]
    y_off = jnp.einsum("cn,hpn->chp", Cm, state) \
        * jnp.exp(dA_cum)[:, :, None]
    # state update
    decay_to_end = jnp.exp(dA_cum[-1:, :] - dA_cum)       # [c, hb]
    wB = Bm[:, None, :] * (decay_to_end * dt)[:, :, None]  # [c, hb, N]
    st_ref[...] = state * jnp.exp(dA_cum[-1, :])[:, None, None] \
        + jnp.einsum("chn,chp->hpn", wB, x)
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, *, chunk: int = 256, head_block: int = 8,
             interpret: bool = False) -> jax.Array:
    """x: [B,T,H,P], dt: [B,T,H], A: [H], Bm/Cm: [B,T,N] -> y [B,T,H,P].

    T must be a chunk multiple (pad upstream); H a head_block multiple."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    hb = min(head_block, H)
    while H % hb:
        hb -= 1
    grid = (B, H // hb, T // chunk)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hb, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, hb), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((hb,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hb, P), lambda b, h, c: (b, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
