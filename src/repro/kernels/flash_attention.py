"""Flash attention forward — Pallas TPU kernel.

TPU-native design (not a CUDA port): the grid is (batch·heads, q-blocks,
kv-blocks) with the kv dimension innermost — TPU executes grid steps
sequentially minor-to-major, so the online-softmax running max/sum/acc
live in VMEM scratch across the kv sweep and the MXU sees
[block_q, d] x [d, block_k] matmuls with 128-aligned tiles.  HBM->VMEM
movement is described entirely by BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # [bq,bk]
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < kv_len
    if causal:
        mask &= kpos <= qpos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    kv_len: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, T, D], k/v: [BH, S, D] -> [BH, T, D].

    T and S are padded to block multiples internally; ``kv_len`` masks the
    valid key prefix (defaults to S)."""
    BH, T, D = q.shape
    S = k.shape[1]
    kv_len = S if kv_len is None else kv_len
    bq, bk = min(block_q, T), min(block_k, S)
    Tp = -(-T // bq) * bq
    Sp = -(-S // bk) * bk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0)))
    grid = (BH, Tp // bq, Sp // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk, kv_len=kv_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, D), q.dtype),
        scratch_shapes=[
            # [bq] running max, [bq] running denom, [bq, D] f32 accumulator
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
