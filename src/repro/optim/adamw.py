"""Optimizers (from scratch — no optax): AdamW and SGD-momentum, plus LR
schedules and global-norm clipping.  Pure element-wise pytree transforms, so
optimizer state inherits parameter sharding under jit.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), n


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: Optional[float] = 1.0

    def init(self, params):
        zeros = lambda p: jax.tree.map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
        return dict(m=zeros(params), v=zeros(params),
                    step=jnp.zeros((), jnp.int32))

    def update(self, params, grads, state):
        step = state["step"] + 1
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mh = m / (1 - b1 ** step.astype(jnp.float32))
            vh = v / (1 - b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay \
                * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, dict(m=new_m, v=new_v, step=step)

    def make_update(self, specs, mesh):
        return self.update


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: Callable | float = 1e-2
    momentum: float = 0.9
    clip_norm: Optional[float] = None

    def init(self, params):
        return dict(mu=jax.tree.map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), params),
            step=jnp.zeros((), jnp.int32))

    def update(self, params, grads, state):
        step = state["step"] + 1
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.lr(step) if callable(self.lr) else self.lr

        def upd(p, g, mu):
            mu = self.momentum * mu + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * mu).astype(p.dtype), mu

        flat_p, treedef = jax.tree.flatten(params)
        out = [upd(p, g, mu) for p, g, mu in
               zip(flat_p, jax.tree.leaves(grads), jax.tree.leaves(state["mu"]))]
        return (jax.tree.unflatten(treedef, [o[0] for o in out]),
                dict(mu=jax.tree.unflatten(treedef, [o[1] for o in out]),
                     step=step))

    def make_update(self, specs, mesh):
        return self.update
