from repro.optim.adamw import AdamW, SGDM, warmup_cosine
