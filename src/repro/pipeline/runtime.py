"""BaPipe distributed runtime: intra-batch pipeline parallelism as
``shard_map`` + ``lax.scan`` + ``lax.ppermute`` on a
("pod",) ("data", "stage", "tensor") mesh.

Execution model (per device, SPMD):

* layer parameters arrive stacked ``[1, Lps, ...]`` (stage-sharded);
* one scan over ``M + S - 1`` ticks; each tick the device applies its stage
  block to its current micro-batch and ``ppermute``s the boundary
  activation to the next stage (a 1-D daisy chain — exactly the paper's
  cluster topology);
* stage 0 injects micro-batches, stage S-1 accumulates outputs;
* the loss is computed on the last stage, masked, and ``psum``-broadcast;
* per-device ``jax.grad`` of that global scalar is SPMD-correct because
  every collective (ppermute/psum/all_gather) transposes to a collective;
* gradients are then ``psum``'d over exactly the axes each leaf is
  replicated on (data/pod for everything; +stage for embed/head/norm) —
  the paper's "orthogonal to data parallelism", literally.

Schedule mapping (paper §3.2 -> TPU): the scan's steady state is 1F1B
(one in-flight micro-batch per stage); ``remat='stage'`` recomputes stage
internals in backward so only the O(S) boundary carries persist — the
paper's 1F1B features-memory row.  ``remat='none'`` stores everything
(GPipe-like).  The sync/async distinction dissolves: XLA issues the
ppermute asynchronously and overlaps it with compute (1F1B-SO behaviour)
without needing the doubled warm-up, which the analytic explorer still
models for GPU/FPGA targets.

Interleaved 1F1B (``1F1B-I``, plan.virtual = V > 1): parameters arrive
stacked ``[1, V, Lc, ...]`` — V non-contiguous layer chunks per device,
chunk v of device n being virtual stage v*S + n — and the tick scan runs
``M*V + S - 1`` ticks with the ppermute daisy chain looping V times.

The per-tick (stage, micro-batch, chunk) assignment is *data*, not
arithmetic: ``make_train_step`` builds the schedule's op table with the
schedule-plan IR (:mod:`repro.core.schedplan`), lowers it to per-element
lookup arrays (:func:`repro.core.schedplan.lower_to_ring`), and the scan
body indexes them — the same compiled order the discrete-event simulator
replays.  ``PipelineConfig.schedule`` selects the order:

* ``1f1b-interleaved`` (the ``auto`` default for V > 1) — streaming chunk
  passes; stage 0 injects fresh micro-batches on pass 0 and re-injects
  ring-returned activations from a ``[M, ...]`` return buffer (parked
  there for M - S ticks; the buffer is gated to stage 0 and elided when
  M == S).  Requires M >= S.
* ``1f1b-interleaved-memlean`` — the Megatron memory-lean order
  (micro-batch groups of S, warm-up ``2(S-n-1) + (V-1)S``): every ring
  return is consumed the very tick it arrives back at stage 0, so the
  [M, ...] return buffer vanishes from the scan carry — the runtime
  realisation of the closed form's ``(V-1)M -> (V-1)S`` features-memory
  drop.  Requires M % S == 0.

Micro-batch positions (``pos3``, VLM M-RoPE) ride the ppermute ring
alongside the activation, so stage s applies the positions of the
micro-batch it actually holds — not stage 0's — whichever schedule is
running.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.core import schedplan as SP
from repro.models import layers as LYR
from repro.models import model as M
from repro.pipeline import stage as ST


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_microbatches: int = 4
    schedule: str = "auto"          # schedplan name: auto | 1f1b |
                                    # 1f1b-interleaved |
                                    # 1f1b-interleaved-memlean | gpipe
    remat: str = "stage"            # none | stage | full
    pod_role: str = "data"          # data | stage  (stage = pipeline over DCN)
    unroll: bool = False            # fully unroll ALL scans (roofline mode)
    gate_ticks: bool = False        # serve: lax.cond-skip invalid ticks so
                                    # devices neither compute nor stream
                                    # weights during fill/drain (real TPUs
                                    # take one conditional branch)
    tick_unroll: int = 0            # >0: unroll factor for the tick scan
                                    # only (two-point roofline differencing);
                                    # inner scans are then fully unrolled

    @property
    def inner_unroll(self) -> bool:
        return self.unroll or self.tick_unroll > 0

    @property
    def tick_scan_unroll(self):
        if self.unroll:
            return True
        return self.tick_unroll if self.tick_unroll > 0 else 1


def _batch_axes(mesh: Mesh, pcfg: PipelineConfig) -> tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if pcfg.pod_role == "stage":
        axes = tuple(a for a in axes if a != "pod")
    return axes


def _stage_axes(mesh: Mesh, pcfg: PipelineConfig):
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        return ("pod", "stage")
    return "stage"


def _n_stages(mesh: Mesh, pcfg: PipelineConfig) -> int:
    s = mesh.shape["stage"]
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        s *= mesh.shape["pod"]
    return s


# ---------------------------------------------------------------------------
# Per-stage block apply (scan over the stage's layers).
# ---------------------------------------------------------------------------

def _gather_fsdp(lp: dict, fsdp_dims: dict, axis: str) -> dict:
    def g(path, leaf):
        name = getattr(path[-1], "key", None)
        dim = fsdp_dims.get(name)
        if dim is None:
            return leaf
        return lax.all_gather(leaf, axis, axis=dim, tiled=True)
    return jax.tree_util.tree_map_with_path(g, lp)


def apply_stage(cfg: ArchConfig, stage_params, stage_meta, x, *,
                pos, pos3=None, cache=None, tp_axis=None, tp_index=None,
                dp_axis=None, dp_index=None, n_dp=1,
                fsdp_axis=None, fsdp_dims=None, remat="stage",
                unroll=False):
    """Scan this stage's Lps layers over activation pytree ``x``.

    ``x`` is the raw hidden state [mb,T,d], or for audio a dict
    {h_enc, h_dec}.  Padded (inactive) layer slots pass through unchanged.
    Returns (x', aux, new_cache)."""

    def layer_body(carry, inp):
        xc, aux = carry
        lp, ml, cl = inp
        if fsdp_axis is not None and fsdp_dims:
            lp = _gather_fsdp(lp, fsdp_dims, fsdp_axis)
        blk_x = dict(h_enc=xc["h_enc"], h_dec=xc["h_dec"]) \
            if isinstance(xc, dict) else xc
        y, new_cl, a = M.block_apply(cfg, lp, blk_x, ml, pos=pos, pos3=pos3,
                                     cache_l=cl, tp_axis=tp_axis,
                                     tp_index=tp_index, dp_axis=dp_axis,
                                     dp_index=dp_index, n_dp=n_dp)
        act = ml["active"]
        y = jax.tree.map(lambda new, old: jnp.where(act, new, old), y, blk_x)
        if new_cl is not None:
            new_cl = jax.tree.map(lambda new, old: jnp.where(act, new, old),
                                  new_cl, cl)
        return (y, aux + jnp.where(act, a, 0.0)), new_cl

    body = jax.checkpoint(layer_body) if remat == "full" else layer_body
    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params, stage_meta, cache),
        unroll=unroll)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Micro-batch preparation (embedding etc., data-parallel, outside the pipe).
# ---------------------------------------------------------------------------

def _prepare_microbatches(cfg: ArchConfig, params, batch, M_: int, tp_index):
    """Returns (inj [M, ...] pytree of per-microbatch injected carries,
    pos [mb,T], pos3 [M,3,mb,T] or None)."""
    if cfg.family == "vlm" and "embeds" in batch:
        x_all = batch["embeds"]
    else:
        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)
    B_loc, T = x_all.shape[0], x_all.shape[1]
    assert B_loc % M_ == 0, f"local batch {B_loc} not divisible by M={M_}"
    mb = B_loc // M_
    pos = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))
    if cfg.family == "audio":
        x_all = x_all + M.sinusoid_pos(
            jnp.broadcast_to(jnp.arange(T)[None], (B_loc, T)), cfg.d_model,
            x_all.dtype)
        frames = batch["frames"].astype(x_all.dtype)
        Sf = frames.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(Sf)[None], (B_loc, Sf))
        h_enc = frames + M.sinusoid_pos(enc_pos, cfg.d_model, x_all.dtype)
        inj = dict(h_dec=x_all.reshape(M_, mb, T, -1),
                   h_enc=h_enc.reshape(M_, mb, Sf, -1))
    else:
        inj = x_all.reshape(M_, mb, T, -1)
    pos3 = None
    if batch.get("pos3") is not None:
        pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, T), 1, 0)
    return inj, pos, pos3, mb, T


def _hidden_of(y):
    return y["h_dec"] if isinstance(y, dict) else y


def _ring_tables(lowering: SP.RingLowering) -> dict:
    """The lowering's per-element lookup arrays as device constants: the
    per-tick (micro-batch, chunk, fresh/direct/park/collect) assignment of
    the compiled schedule, indexed by ``e = tick - stage`` in the scan."""
    return dict(
        m=jnp.asarray(lowering.m_of_e, jnp.int32),
        v=jnp.asarray(lowering.v_of_e, jnp.int32),
        fresh=jnp.asarray(lowering.fresh, bool),
        direct=jnp.asarray(lowering.direct, bool),
        park=jnp.asarray(lowering.park, bool),
        collect=jnp.asarray(lowering.collect, bool))


def _at(table: jnp.ndarray, idx):
    return lax.dynamic_index_in_dim(table, idx, 0, keepdims=False)


def _ring_ingest(tab: dict, MV: int, S: int, stage_idx, t, inj, x_cur,
                 retbuf):
    """Stage-0 ring ingestion for one tick of the compiled schedule: park
    the arriving ring return (when the schedule buffers; stage 0 only),
    then select this tick's stage-0 source — fresh injection (chunk-0
    pass), the ring return straight off the ppermute carry (``direct``),
    or the parked return.  ``retbuf`` is None for schedules that consume
    every return the tick it arrives.  Returns (retbuf, x_in)."""
    if retbuf is not None:
        e_arr = t - S
        eacl = jnp.clip(e_arr, 0, MV - 1)
        do_park = ((e_arr >= 0) & _at(tab["park"], eacl)
                   & (stage_idx == 0))
        slot = _at(tab["m"], eacl)

        def park(rb, c):
            old = lax.dynamic_index_in_dim(rb, slot, 0, keepdims=False)
            return lax.dynamic_update_index_in_dim(
                rb, jnp.where(do_park, c, old), slot, 0)

        retbuf = jax.tree.map(park, retbuf, x_cur)
    e0 = jnp.clip(t, 0, MV - 1)
    m0 = _at(tab["m"], e0)
    is_fresh = _at(tab["fresh"], e0)
    if retbuf is not None:
        take_direct = _at(tab["direct"], e0)
        src = jax.tree.map(
            lambda q, rb, c: jnp.where(
                is_fresh,
                lax.dynamic_index_in_dim(q, m0, 0, keepdims=False),
                jnp.where(take_direct, c,
                          lax.dynamic_index_in_dim(rb, m0, 0,
                                                   keepdims=False))),
            inj, retbuf, x_cur)
    else:
        src = jax.tree.map(
            lambda q, c: jnp.where(
                is_fresh,
                lax.dynamic_index_in_dim(q, m0, 0, keepdims=False),
                c),
            inj, x_cur)
    x_in = jax.tree.map(
        lambda s_, c: jnp.where(stage_idx == 0, s_, c), src, x_cur)
    return retbuf, x_in


# ---------------------------------------------------------------------------
# Training step factory.
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, optimizer=None,
                    param_dtype=jnp.float32):
    """Build the jitted pipeline train step.

    Returns (step_fn, specs): without an optimizer ``step_fn(params, batch)
    -> (loss, grads)``; with one ``step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)``."""
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S}); " \
        f"with pod_role='stage' build the plan with n_stages=pod*stages"
    V = plan.virtual
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"], virtual=V)
    M_ = pcfg.n_microbatches
    # compile the schedule's op table and lower it onto the ring: the
    # per-tick (stage, micro-batch, chunk) assignment becomes lookup data
    sched = SP.resolve_ring_schedule(pcfg.schedule, V)
    lowering = SP.lower_to_ring(SP.build_schedule(sched, M_, S, V))
    fsdp_dims = ST.fsdp_scan_dims(specs, virtual=V) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1
    n_batch_shards = math.prod(mesh.shape[a] for a in batch_axes) or 1

    def batch_spec_for(keys):
        spec = {}
        for k in keys:
            if k in ("tokens", "labels"):
                spec[k] = P(batch_axes, None)
            elif k in ("embeds", "frames"):
                spec[k] = P(batch_axes, None, None)
            elif k == "pos3":
                spec[k] = P(None, batch_axes, None)
        return spec

    def global_loss(params, batch):
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])
        inj, pos, pos3, mb, T = _prepare_microbatches(
            cfg, params, batch, M_, tp_index)
        # ring payload: the boundary activation plus, when present, the
        # micro-batch's pos3 — positions travel WITH the micro-batch, so
        # stage s applies the positions of the micro-batch it holds
        ring_inj = {"x": inj}
        if pos3 is not None:
            ring_inj["p3"] = pos3
        tab = _ring_tables(lowering)
        MV = M_ * V
        use_retbuf = lowering.needs_retbuf

        def tick(carry, t):
            if use_retbuf:
                x_cur, outbuf, retbuf, aux = carry
            else:
                x_cur, outbuf, aux = carry
                retbuf = None
            retbuf, x_in = _ring_ingest(tab, MV, S, stage_idx, t,
                                        ring_inj, x_cur, retbuf)
            p3 = x_in.get("p3")
            e_idx = t - stage_idx
            ecl = jnp.clip(e_idx, 0, MV - 1)
            if V > 1:
                chunk = _at(tab["v"], ecl)
                lp_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    lp_local)
                sm_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    smeta_local)
            else:
                lp_t, sm_t = lp_local, smeta_local

            def stage_fn(x_in):
                y, a, _ = apply_stage(
                    cfg, lp_t, sm_t, x_in, pos=pos, pos3=p3,
                    cache=None, tp_axis="tensor", tp_index=tp_index,
                    dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                    fsdp_axis="data" if cfg.fsdp else None,
                    fsdp_dims=fsdp_dims, remat=pcfg.remat,
                    unroll=pcfg.inner_unroll)
                return y, a

            if pcfg.remat == "stage_save_moe":
                # collective-aware remat: keep expert outputs (so backward
                # never re-runs the MoE all_to_alls), recompute the rest
                stage_fn = jax.checkpoint(
                    stage_fn,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "moe_y"))
            elif pcfg.remat in ("stage", "full"):
                stage_fn = jax.checkpoint(stage_fn)
            y, a = stage_fn(x_in["x"])
            # ticks outside this stage's window process garbage: gate aux
            a = jnp.where((e_idx >= 0) & (e_idx < MV), a, 0.0)
            # last stage collects a finished micro-batch (chunk V-1 output)
            out_e = t - (S - 1)
            oecl = jnp.clip(out_e, 0, MV - 1)
            oc = _at(tab["m"], oecl)
            do_collect = ((out_e >= 0) & _at(tab["collect"], oecl)
                          & (stage_idx == S - 1))
            cur = lax.dynamic_index_in_dim(outbuf, oc, 0, keepdims=False)
            wr = jnp.where(do_collect, _hidden_of(y), cur)
            outbuf = lax.dynamic_update_index_in_dim(outbuf, wr, oc, 0)
            # daisy-chain shift (activation + its pos3 together)
            y_ring = dict(x_in, x=y)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = jax.tree.map(lambda a: lax.ppermute(a, stage_ax, perm),
                                  y_ring)
            if use_retbuf:
                return (x_next, outbuf, retbuf, aux + a), None
            return (x_next, outbuf, aux + a), None

        x0 = jax.tree.map(lambda q: jnp.zeros_like(q[0]), ring_inj)
        outbuf0 = jnp.zeros((M_, mb, T, cfg.d_model),
                            _hidden_of(x0["x"]).dtype)
        carry0 = (x0, outbuf0, jnp.zeros((), jnp.float32))
        if use_retbuf:
            retbuf0 = jax.tree.map(jnp.zeros_like, ring_inj)
            carry0 = (x0, outbuf0, retbuf0, jnp.zeros((), jnp.float32))
        carry_out, _ = lax.scan(
            tick, carry0,
            jnp.arange(lowering.n_ticks), unroll=pcfg.tick_scan_unroll)
        outbuf, aux = carry_out[1], carry_out[-1]

        h = LYR.rms_norm(outbuf.reshape(M_ * mb, T, -1), params["final_norm"],
                         cfg.norm_eps)
        ce = M.logits_and_xent(cfg, params, h, batch["labels"], "tensor",
                               tp_index)
        on_last = (stage_idx == S - 1).astype(jnp.float32)
        # Per-device LOCAL term of the global loss: global = psum(local).
        # (Under check_rep=False shard_map, psum transposes to psum, so
        # the scalar we differentiate must be the local contribution, with
        # tensor-replication divided out.)
        tp_size = mesh.shape["tensor"]
        return (ce * on_last + aux / M_) / (n_batch_shards * tp_size)

    def sharded_step(params, batch):
        local, grads = jax.value_and_grad(global_loss)(params, batch)
        loss = lax.psum(local, mesh_axes)
        grads = jax.tree.map(
            lambda g, s: lax.psum(g, axes)
            if (axes := ST.grad_sync_axes(s, mesh_axes)) else g,
            grads, specs)
        return loss, grads

    _built: dict = {}

    def fn(params, batch):
        keys = tuple(sorted(batch))
        if keys not in _built:
            _built[keys] = shard_map(
                sharded_step, mesh=mesh,
                in_specs=(specs, batch_spec_for(keys)),
                out_specs=(P(), specs), check_rep=False)
        return _built[keys](params, batch)

    if optimizer is None:
        return jax.jit(fn), specs

    opt_update = optimizer.make_update(specs, mesh)

    def full_step(params, opt_state, batch):
        loss, grads = fn(params, batch)
        params, opt_state = opt_update(params, grads, opt_state)
        return params, opt_state, dict(loss=loss)

    return jax.jit(full_step, donate_argnums=(0, 1)), specs


# ---------------------------------------------------------------------------
# Serving: pipelined decode (and prefill).
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, cache_shapes, batch_axes, *,
                b_sharded: bool, stage_axis="stage", virtual: int = 1):
    """Stage-sharded cache specs: every leaf is [S, Lps, B, ...] — or
    [S, V, Lc, B, ...] for an interleaved (virtual > 1) plan, which shifts
    the positional dims right by one.  Attention K/V caches additionally
    shard their head dim over tensor."""
    off = 0 if virtual == 1 else 1
    def leaf(path, l):
        name = getattr(path[-1], "key", None)
        if name == "len":
            return P(*([stage_axis] + [None] * (l.ndim - 1)))
        spec = [stage_axis, None] + [None] * (l.ndim - 2)
        if b_sharded and l.ndim >= 3 + off:
            spec[2 + off] = batch_axes
        if name in ("k", "v", "xk", "xv") and l.ndim >= 6 + off:
            spec[4 + off] = "tensor"   # [S, (V,) Lps, B, len, heads, hd]
        return P(*spec)
    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def init_pipeline_cache(cfg: ArchConfig, plan: ST.StagePlan, batch: int,
                        max_len: int, *, dtype=jnp.float32, enc_len: int = 0):
    """Global cache [S, Lps, B, ...] (call under jit with sharding, or use
    eval_shape for the dry run).

    When n_kv_heads doesn't divide the tensor axis, the cache carries
    ``tensor`` head slots (one per device) — the inherent duplication of
    serving few-KV-head models under tensor parallelism."""
    tp = plan.tensor
    nkv = cfg.n_kv_heads
    if cfg.attn_kind == "gqa" and nkv % tp != 0:
        nh_l = max(1, cfg.n_heads // tp)
        g = cfg.n_heads // nkv
        nkv = tp * max(1, nh_l // g)
    pad_cfg = dataclasses.replace(cfg, n_layers=plan.n_layers_padded,
                                  n_kv_heads=nkv)
    c = M.init_cache(pad_cfg, batch, max_len, tp=1, dtype=dtype,
                     enc_len=enc_len)
    return jax.tree.map(lambda a: ST._stack_chunks(a, plan), c)


def _restore_len(c_new, c_old):
    """Copy 'len' counters back from c_old into c_new."""
    def pick(path, new, old):
        return old if getattr(path[-1], "key", None) == "len" else new
    return jax.tree_util.tree_map_with_path(pick, c_new, c_old)


def _advance_len(cache, q_len: int):
    def bump(path, leaf):
        return leaf + q_len if getattr(path[-1], "key", None) == "len" else leaf
    return jax.tree_util.tree_map_with_path(bump, cache)


def make_serve_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, batch_sharded: bool = True,
                    param_dtype=jnp.float32, cache_dtype=jnp.float32,
                    max_len: int = 0, global_batch: int = 0, q_len: int = 1,
                    enc_len: int = 0):
    """Build the jitted pipelined decode/prefill step:
    ``serve_step(params, cache, batch) -> (last_logits, cache)``.

    ``q_len=1`` is one-token decode; ``q_len=seq`` is prefill (KV/SSM cache
    populated, logits returned for the last position).  Micro-batches split
    the batch dimension; the per-stage cache is [Lps, B_loc, ...] and each
    tick dynamic-slices its micro-batch rows.  Cache ``len`` counters are
    frozen during the tick scan (every micro-batch writes at the same
    offset) and advanced once at the end.

    Interleaved (``plan.virtual`` = V > 1) plans are supported for the
    *prefill* phase only: prefill is throughput-bound, so shrinking the
    flush bubble by V pays, and the tick scan replays the same compiled
    schedule table as training (cache leaves are [V, Lc, B, ...]; each
    tick chunk-indexes them).  One-token decode is latency-bound — every
    extra ring lap adds S hops to the token's critical path — so
    ``q_len == 1`` with V > 1 still raises.
    """
    V = plan.virtual
    if V != 1 and q_len == 1:
        raise NotImplementedError(
            "pipelined decode does not support interleaved (virtual>1) "
            "plans; decode is latency-bound, not flush-bubble-bound — "
            "use plan_stages(cfg, virtual=1) for decode (prefill may "
            "keep V > 1)")
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S})"
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"], virtual=V)
    M_ = pcfg.n_microbatches
    sched = SP.resolve_ring_schedule(pcfg.schedule, V)
    lowering = SP.lower_to_ring(SP.build_schedule(sched, M_, S, V))
    fsdp_dims = ST.fsdp_scan_dims(specs, virtual=V) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1

    cache_shapes = jax.eval_shape(
        functools.partial(init_pipeline_cache, cfg, plan, global_batch,
                          max_len, dtype=cache_dtype, enc_len=enc_len))
    cspecs = cache_specs(cfg, cache_shapes, batch_axes,
                         b_sharded=batch_sharded, stage_axis=stage_ax,
                         virtual=V)
    batch_spec = dict(tokens=P(batch_axes if batch_sharded else None, None))
    if cfg.family == "vlm":
        batch_spec["pos3"] = P(None, batch_axes if batch_sharded else None, None)

    tab = _ring_tables(lowering)
    MV = M_ * V
    use_retbuf = lowering.needs_retbuf

    def sharded_decode(params, cache, batch):
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])
        cache_local = jax.tree.map(lambda a: a[0], cache)

        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)           # [B_loc,q,d]
        B_loc = x_all.shape[0]
        assert B_loc % M_ == 0
        mb = B_loc // M_
        cur_len = jnp.asarray(M._cache_len(cache_local), jnp.int32)
        pos1 = cur_len + jnp.arange(q_len, dtype=jnp.int32)
        if cfg.family == "audio":
            x_all = x_all + M.sinusoid_pos(
                jnp.broadcast_to(pos1[None], (B_loc, q_len)),
                cfg.d_model, x_all.dtype)
        inj = x_all.reshape(M_, mb, q_len, -1)
        if cfg.family == "audio":
            # decode consumes the cross K/V cache; h_enc is vestigial
            inj = dict(h_dec=inj,
                       h_enc=jnp.zeros((M_, mb, 1, cfg.d_model), x_all.dtype))
        pos = jnp.broadcast_to(pos1[None], (mb, q_len))
        pos3 = None
        if batch.get("pos3") is not None:
            pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, q_len), 1, 0)

        def tick(carry, t):
            if use_retbuf:
                x_cur, cache_l, outbuf, retbuf = carry
            else:
                x_cur, cache_l, outbuf = carry
                retbuf = None
            retbuf, x_in = _ring_ingest(tab, MV, S, stage_idx, t,
                                        inj, x_cur, retbuf)
            # element (micro-batch, chunk) this stage works on at tick t
            e_idx = t - stage_idx
            valid = (e_idx >= 0) & (e_idx < MV)
            ecl = jnp.clip(e_idx, 0, MV - 1)
            mc = _at(tab["m"], ecl)
            if V > 1:
                chunk = _at(tab["v"], ecl)
                lp_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    lp_local)
                sm_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    smeta_local)
                cache_chunk = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    cache_l)
            else:
                lp_t, sm_t, cache_chunk = lp_local, smeta_local, cache_l
            # slice this micro-batch's cache rows ([Lc, B_loc, ...] leaves;
            # 'len' counters are [Lc] and pass through whole)
            c_mb = jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(a, mc * mb, mb, 1)
                if a.ndim >= 2 else a, cache_chunk)
            p3 = None if pos3 is None else pos3[mc]

            def _run(args):
                x_in, c_mb = args
                y, _, c_new = apply_stage(
                    cfg, lp_t, sm_t, x_in, pos=pos, pos3=p3,
                    cache=c_mb, tp_axis="tensor", tp_index=tp_index,
                    dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                    fsdp_axis="data" if cfg.fsdp else None,
                    fsdp_dims=fsdp_dims, remat="none",
                    unroll=pcfg.inner_unroll)
                return y, c_new

            if pcfg.gate_ticks:
                # validity is uniform across (data, tensor) for a fixed
                # (stage, tick), so collectives inside the branch are safe
                y, c_new = lax.cond(valid, _run, lambda a: a, (x_in, c_mb))
            else:
                y, c_new = _run((x_in, c_mb))
            # write back only when this tick was valid for this stage;
            # freeze 'len' counters (all micro-batches share the offset)
            c_new = jax.tree.map(
                lambda new, old: jnp.where(valid, new, old), c_new, c_mb)
            c_new = _restore_len(c_new, c_mb)
            cache_chunk = jax.tree.map(
                lambda full, blk: lax.dynamic_update_slice_in_dim(
                    full, blk.astype(full.dtype), mc * mb, 1)
                if full.ndim >= 2 else blk, cache_chunk, c_new)
            if V > 1:
                cache_l = jax.tree.map(
                    lambda full, blk: lax.dynamic_update_index_in_dim(
                        full, blk.astype(full.dtype), chunk, 0),
                    cache_l, cache_chunk)
            else:
                cache_l = cache_chunk
            # last stage emits the final (chunk V-1) last-position hidden
            out_e = t - (S - 1)
            oecl = jnp.clip(out_e, 0, MV - 1)
            oc = _at(tab["m"], oecl)
            do_collect = ((out_e >= 0) & _at(tab["collect"], oecl)
                          & (stage_idx == S - 1))
            curo = lax.dynamic_index_in_dim(outbuf, oc, 0, keepdims=False)
            wr = jnp.where(do_collect, _hidden_of(y)[:, -1:], curo)
            outbuf = lax.dynamic_update_index_in_dim(outbuf, wr, oc, 0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = jax.tree.map(lambda a: lax.ppermute(a, stage_ax, perm), y)
            if use_retbuf:
                return (x_next, cache_l, outbuf, retbuf), None
            return (x_next, cache_l, outbuf), None

        x0 = jax.tree.map(lambda q: jnp.zeros_like(q[0]), inj)
        outbuf0 = jnp.zeros((M_, mb, 1, cfg.d_model), x_all.dtype)
        carry0 = (x0, cache_local, outbuf0)
        if use_retbuf:
            carry0 = carry0 + (jax.tree.map(jnp.zeros_like, inj),)
        carry_out, _ = lax.scan(
            tick, carry0, jnp.arange(lowering.n_ticks),
            unroll=pcfg.tick_scan_unroll)
        cache_local, outbuf = carry_out[1], carry_out[2]
        cache_local = _advance_len(cache_local, q_len)

        h = LYR.rms_norm(outbuf.reshape(B_loc, 1, -1), params["final_norm"],
                         cfg.norm_eps)
        table = params.get("head", params["embed"])
        logits = (h @ table.T).astype(jnp.float32)
        # broadcast real logits from the last stage to every stage
        on_last = (stage_idx == S - 1).astype(logits.dtype)
        logits = lax.psum(logits * on_last, stage_ax)
        new_cache = jax.tree.map(lambda a: a[None], cache_local)
        return logits, new_cache

    fn = shard_map(
        sharded_decode, mesh=mesh,
        in_specs=(specs, cspecs, batch_spec),
        out_specs=(P(batch_axes if batch_sharded else None, None, "tensor"),
                   cspecs),
        check_rep=False)
    return jax.jit(fn, donate_argnums=(1,)), specs, cspecs, cache_shapes
