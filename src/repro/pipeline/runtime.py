"""BaPipe distributed runtime: intra-batch pipeline parallelism as
``shard_map`` + ``lax.scan`` + ``lax.ppermute`` on a
("pod",) ("data", "stage", "tensor") mesh.

Execution model (per device, SPMD):

* layer parameters arrive stacked ``[1, Lps, ...]`` (stage-sharded);
* one scan over ``M + S - 1`` ticks; each tick the device applies its stage
  block to its current micro-batch and ``ppermute``s the boundary
  activation to the next stage (a 1-D daisy chain — exactly the paper's
  cluster topology);
* stage 0 injects micro-batches, stage S-1 accumulates outputs;
* the loss is computed on the last stage, masked, and ``psum``-broadcast;
* per-device ``jax.grad`` of that global scalar is SPMD-correct because
  every collective (ppermute/psum/all_gather) transposes to a collective;
* gradients are then ``psum``'d over exactly the axes each leaf is
  replicated on (data/pod for everything; +stage for embed/head/norm) —
  the paper's "orthogonal to data parallelism", literally.

Schedule mapping (paper §3.2 -> TPU): the scan's steady state is 1F1B
(one in-flight micro-batch per stage); ``remat='stage'`` recomputes stage
internals in backward so only the O(S) boundary carries persist — the
paper's 1F1B features-memory row.  ``remat='none'`` stores everything
(GPipe-like).  The sync/async distinction dissolves: XLA issues the
ppermute asynchronously and overlaps it with compute (1F1B-SO behaviour)
without needing the doubled warm-up, which the analytic explorer still
models for GPU/FPGA targets.

Interleaved 1F1B (``1F1B-I``, plan.virtual = V > 1): parameters arrive
stacked ``[1, V, Lc, ...]`` — V non-contiguous layer chunks per device,
chunk v of device n being virtual stage v*S + n — and the tick scan runs
``M*V + S - 1`` ticks with the ppermute daisy chain looping V times.  Each
tick the device selects chunk ``(t - stage) // M``; stage 0 injects fresh
micro-batches on pass 0 and re-injects ring-returned activations (a
``[M, ...]`` return buffer) on later passes, so the pipeline-flush bubble
shrinks by V, matching ``eval_1f1b_interleaved`` and the discrete-event
simulator's ``1F1B-I`` order.  Requires M >= S so chunk passes stream
without stalling.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.models import layers as LYR
from repro.models import model as M
from repro.pipeline import stage as ST


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_microbatches: int = 4
    remat: str = "stage"            # none | stage | full
    pod_role: str = "data"          # data | stage  (stage = pipeline over DCN)
    unroll: bool = False            # fully unroll ALL scans (roofline mode)
    gate_ticks: bool = False        # serve: lax.cond-skip invalid ticks so
                                    # devices neither compute nor stream
                                    # weights during fill/drain (real TPUs
                                    # take one conditional branch)
    tick_unroll: int = 0            # >0: unroll factor for the tick scan
                                    # only (two-point roofline differencing);
                                    # inner scans are then fully unrolled

    @property
    def inner_unroll(self) -> bool:
        return self.unroll or self.tick_unroll > 0

    @property
    def tick_scan_unroll(self):
        if self.unroll:
            return True
        return self.tick_unroll if self.tick_unroll > 0 else 1


def _batch_axes(mesh: Mesh, pcfg: PipelineConfig) -> tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if pcfg.pod_role == "stage":
        axes = tuple(a for a in axes if a != "pod")
    return axes


def _stage_axes(mesh: Mesh, pcfg: PipelineConfig):
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        return ("pod", "stage")
    return "stage"


def _n_stages(mesh: Mesh, pcfg: PipelineConfig) -> int:
    s = mesh.shape["stage"]
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        s *= mesh.shape["pod"]
    return s


# ---------------------------------------------------------------------------
# Per-stage block apply (scan over the stage's layers).
# ---------------------------------------------------------------------------

def _gather_fsdp(lp: dict, fsdp_dims: dict, axis: str) -> dict:
    def g(path, leaf):
        name = getattr(path[-1], "key", None)
        dim = fsdp_dims.get(name)
        if dim is None:
            return leaf
        return lax.all_gather(leaf, axis, axis=dim, tiled=True)
    return jax.tree_util.tree_map_with_path(g, lp)


def apply_stage(cfg: ArchConfig, stage_params, stage_meta, x, *,
                pos, pos3=None, cache=None, tp_axis=None, tp_index=None,
                dp_axis=None, dp_index=None, n_dp=1,
                fsdp_axis=None, fsdp_dims=None, remat="stage",
                unroll=False):
    """Scan this stage's Lps layers over activation pytree ``x``.

    ``x`` is the raw hidden state [mb,T,d], or for audio a dict
    {h_enc, h_dec}.  Padded (inactive) layer slots pass through unchanged.
    Returns (x', aux, new_cache)."""

    def layer_body(carry, inp):
        xc, aux = carry
        lp, ml, cl = inp
        if fsdp_axis is not None and fsdp_dims:
            lp = _gather_fsdp(lp, fsdp_dims, fsdp_axis)
        blk_x = dict(h_enc=xc["h_enc"], h_dec=xc["h_dec"]) \
            if isinstance(xc, dict) else xc
        y, new_cl, a = M.block_apply(cfg, lp, blk_x, ml, pos=pos, pos3=pos3,
                                     cache_l=cl, tp_axis=tp_axis,
                                     tp_index=tp_index, dp_axis=dp_axis,
                                     dp_index=dp_index, n_dp=n_dp)
        act = ml["active"]
        y = jax.tree.map(lambda new, old: jnp.where(act, new, old), y, blk_x)
        if new_cl is not None:
            new_cl = jax.tree.map(lambda new, old: jnp.where(act, new, old),
                                  new_cl, cl)
        return (y, aux + jnp.where(act, a, 0.0)), new_cl

    body = jax.checkpoint(layer_body) if remat == "full" else layer_body
    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params, stage_meta, cache),
        unroll=unroll)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Micro-batch preparation (embedding etc., data-parallel, outside the pipe).
# ---------------------------------------------------------------------------

def _prepare_microbatches(cfg: ArchConfig, params, batch, M_: int, tp_index):
    """Returns (inj [M, ...] pytree of per-microbatch injected carries,
    pos [mb,T], pos3 [M,3,mb,T] or None)."""
    if cfg.family == "vlm" and "embeds" in batch:
        x_all = batch["embeds"]
    else:
        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)
    B_loc, T = x_all.shape[0], x_all.shape[1]
    assert B_loc % M_ == 0, f"local batch {B_loc} not divisible by M={M_}"
    mb = B_loc // M_
    pos = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))
    if cfg.family == "audio":
        x_all = x_all + M.sinusoid_pos(
            jnp.broadcast_to(jnp.arange(T)[None], (B_loc, T)), cfg.d_model,
            x_all.dtype)
        frames = batch["frames"].astype(x_all.dtype)
        Sf = frames.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(Sf)[None], (B_loc, Sf))
        h_enc = frames + M.sinusoid_pos(enc_pos, cfg.d_model, x_all.dtype)
        inj = dict(h_dec=x_all.reshape(M_, mb, T, -1),
                   h_enc=h_enc.reshape(M_, mb, Sf, -1))
    else:
        inj = x_all.reshape(M_, mb, T, -1)
    pos3 = None
    if batch.get("pos3") is not None:
        pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, T), 1, 0)
    return inj, pos, pos3, mb, T


def _hidden_of(y):
    return y["h_dec"] if isinstance(y, dict) else y


# ---------------------------------------------------------------------------
# Training step factory.
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, optimizer=None,
                    param_dtype=jnp.float32):
    """Build the jitted pipeline train step.

    Returns (step_fn, specs): without an optimizer ``step_fn(params, batch)
    -> (loss, grads)``; with one ``step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)``."""
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S}); " \
        f"with pod_role='stage' build the plan with n_stages=pod*stages"
    V = plan.virtual
    assert V == 1 or not cfg.fsdp, "1F1B-I (virtual>1) with fsdp unsupported"
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"], virtual=V)
    M_ = pcfg.n_microbatches
    assert V == 1 or M_ >= S, \
        f"1F1B-I needs n_microbatches ({M_}) >= stages ({S}) to stream " \
        f"chunk passes through the ring"
    fsdp_dims = ST.fsdp_scan_dims(specs) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1
    n_batch_shards = math.prod(mesh.shape[a] for a in batch_axes) or 1

    def batch_spec_for(keys):
        spec = {}
        for k in keys:
            if k in ("tokens", "labels"):
                spec[k] = P(batch_axes, None)
            elif k in ("embeds", "frames"):
                spec[k] = P(batch_axes, None, None)
            elif k == "pos3":
                spec[k] = P(None, batch_axes, None)
        return spec

    def global_loss(params, batch):
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])
        inj, pos, pos3, mb, T = _prepare_microbatches(
            cfg, params, batch, M_, tp_index)

        def tick(carry, t):
            if V > 1:
                x_cur, outbuf, retbuf, aux = carry
                # a pass that looped back from the last stage arrives S
                # ticks after it entered; park it until its next pass
                e_arr = t - S
                ok_arr = (e_arr >= 0) & (e_arr < M_ * (V - 1))
                slot = jnp.clip(e_arr, 0, M_ * (V - 1) - 1) % M_

                def park(rb, c):
                    old = lax.dynamic_index_in_dim(rb, slot, 0,
                                                   keepdims=False)
                    return lax.dynamic_update_index_in_dim(
                        rb, jnp.where(ok_arr, c, old), slot, 0)

                retbuf = jax.tree.map(park, retbuf, x_cur)
            else:
                x_cur, outbuf, aux = carry
                retbuf = None
            tcl = jnp.clip(t, 0, M_ - 1)
            m0 = jnp.clip(t, 0, M_ * V - 1) % M_    # stage-0 micro-batch
            if V > 1:
                src = jax.tree.map(
                    lambda q, rb: jnp.where(
                        t < M_, q[tcl],
                        lax.dynamic_index_in_dim(rb, m0, 0, keepdims=False)),
                    inj, retbuf)
            else:
                src = jax.tree.map(lambda q: q[tcl], inj)
            x_in = jax.tree.map(
                lambda s_, c: jnp.where(stage_idx == 0, s_, c), src, x_cur)
            p3 = None if pos3 is None else pos3[m0]
            if V > 1:
                chunk = jnp.clip((t - stage_idx) // M_, 0, V - 1)
                lp_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    lp_local)
                sm_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    smeta_local)
            else:
                lp_t, sm_t = lp_local, smeta_local

            def stage_fn(x_in):
                y, a, _ = apply_stage(
                    cfg, lp_t, sm_t, x_in, pos=pos, pos3=p3,
                    cache=None, tp_axis="tensor", tp_index=tp_index,
                    dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                    fsdp_axis="data" if cfg.fsdp else None,
                    fsdp_dims=fsdp_dims, remat=pcfg.remat,
                    unroll=pcfg.inner_unroll)
                return y, a

            if pcfg.remat == "stage_save_moe":
                # collective-aware remat: keep expert outputs (so backward
                # never re-runs the MoE all_to_alls), recompute the rest
                stage_fn = jax.checkpoint(
                    stage_fn,
                    policy=jax.checkpoint_policies.save_only_these_names(
                        "moe_y"))
            elif pcfg.remat in ("stage", "full"):
                stage_fn = jax.checkpoint(stage_fn)
            y, a = stage_fn(x_in)
            # ticks outside this stage's window process garbage: gate aux
            e_idx = t - stage_idx
            a = jnp.where((e_idx >= 0) & (e_idx < M_ * V), a, 0.0)
            # last stage collects its finished micro-batch (final pass only)
            out_t = t - (S - 1)
            oc = jnp.clip(out_t - M_ * (V - 1), 0, M_ - 1)
            cur = lax.dynamic_index_in_dim(outbuf, oc, 0, keepdims=False)
            wr = jnp.where((out_t >= M_ * (V - 1)) & (stage_idx == S - 1),
                           _hidden_of(y), cur)
            outbuf = lax.dynamic_update_index_in_dim(outbuf, wr, oc, 0)
            # daisy-chain shift
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = jax.tree.map(lambda a: lax.ppermute(a, stage_ax, perm), y)
            if V > 1:
                return (x_next, outbuf, retbuf, aux + a), None
            return (x_next, outbuf, aux + a), None

        x0 = jax.tree.map(lambda q: jnp.zeros_like(q[0]), inj)
        outbuf0 = jnp.zeros((M_, mb, T, cfg.d_model),
                            _hidden_of(x0).dtype)
        carry0 = (x0, outbuf0, jnp.zeros((), jnp.float32))
        if V > 1:
            retbuf0 = jax.tree.map(jnp.zeros_like, inj)
            carry0 = (x0, outbuf0, retbuf0, jnp.zeros((), jnp.float32))
        carry_out, _ = lax.scan(
            tick, carry0,
            jnp.arange(M_ * V + S - 1), unroll=pcfg.tick_scan_unroll)
        outbuf, aux = carry_out[1], carry_out[-1]

        h = LYR.rms_norm(outbuf.reshape(M_ * mb, T, -1), params["final_norm"],
                         cfg.norm_eps)
        ce = M.logits_and_xent(cfg, params, h, batch["labels"], "tensor",
                               tp_index)
        on_last = (stage_idx == S - 1).astype(jnp.float32)
        # Per-device LOCAL term of the global loss: global = psum(local).
        # (Under check_rep=False shard_map, psum transposes to psum, so
        # the scalar we differentiate must be the local contribution, with
        # tensor-replication divided out.)
        tp_size = mesh.shape["tensor"]
        return (ce * on_last + aux / M_) / (n_batch_shards * tp_size)

    def sharded_step(params, batch):
        local, grads = jax.value_and_grad(global_loss)(params, batch)
        loss = lax.psum(local, mesh_axes)
        grads = jax.tree.map(
            lambda g, s: lax.psum(g, axes)
            if (axes := ST.grad_sync_axes(s, mesh_axes)) else g,
            grads, specs)
        return loss, grads

    _built: dict = {}

    def fn(params, batch):
        keys = tuple(sorted(batch))
        if keys not in _built:
            _built[keys] = shard_map(
                sharded_step, mesh=mesh,
                in_specs=(specs, batch_spec_for(keys)),
                out_specs=(P(), specs), check_rep=False)
        return _built[keys](params, batch)

    if optimizer is None:
        return jax.jit(fn), specs

    opt_update = optimizer.make_update(specs, mesh)

    def full_step(params, opt_state, batch):
        loss, grads = fn(params, batch)
        params, opt_state = opt_update(params, grads, opt_state)
        return params, opt_state, dict(loss=loss)

    return jax.jit(full_step, donate_argnums=(0, 1)), specs


# ---------------------------------------------------------------------------
# Serving: pipelined decode (and prefill).
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, cache_shapes, batch_axes, *,
                b_sharded: bool, stage_axis="stage"):
    """Stage-sharded cache specs: every leaf is [S, Lps, B, ...].
    Attention K/V caches additionally shard their head dim over tensor."""
    def leaf(path, l):
        name = getattr(path[-1], "key", None)
        if name == "len":
            return P(stage_axis, None)
        spec = [stage_axis, None] + [None] * (l.ndim - 2)
        if b_sharded and l.ndim >= 3:
            spec[2] = batch_axes
        if name in ("k", "v", "xk", "xv") and l.ndim >= 6:
            spec[4] = "tensor"       # [S, Lps, B, len, heads, hd]
        return P(*spec)
    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def init_pipeline_cache(cfg: ArchConfig, plan: ST.StagePlan, batch: int,
                        max_len: int, *, dtype=jnp.float32, enc_len: int = 0):
    """Global cache [S, Lps, B, ...] (call under jit with sharding, or use
    eval_shape for the dry run).

    When n_kv_heads doesn't divide the tensor axis, the cache carries
    ``tensor`` head slots (one per device) — the inherent duplication of
    serving few-KV-head models under tensor parallelism."""
    tp = plan.tensor
    nkv = cfg.n_kv_heads
    if cfg.attn_kind == "gqa" and nkv % tp != 0:
        nh_l = max(1, cfg.n_heads // tp)
        g = cfg.n_heads // nkv
        nkv = tp * max(1, nh_l // g)
    pad_cfg = dataclasses.replace(cfg, n_layers=plan.n_layers_padded,
                                  n_kv_heads=nkv)
    c = M.init_cache(pad_cfg, batch, max_len, tp=1, dtype=dtype,
                     enc_len=enc_len)
    return jax.tree.map(lambda a: ST._stack_chunks(a, plan), c)


def _restore_len(c_new, c_old):
    """Copy 'len' counters back from c_old into c_new."""
    def pick(path, new, old):
        return old if getattr(path[-1], "key", None) == "len" else new
    return jax.tree_util.tree_map_with_path(pick, c_new, c_old)


def _advance_len(cache, q_len: int):
    def bump(path, leaf):
        return leaf + q_len if getattr(path[-1], "key", None) == "len" else leaf
    return jax.tree_util.tree_map_with_path(bump, cache)


def make_serve_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, batch_sharded: bool = True,
                    param_dtype=jnp.float32, cache_dtype=jnp.float32,
                    max_len: int = 0, global_batch: int = 0, q_len: int = 1,
                    enc_len: int = 0):
    """Build the jitted pipelined decode/prefill step:
    ``serve_step(params, cache, batch) -> (last_logits, cache)``.

    ``q_len=1`` is one-token decode; ``q_len=seq`` is prefill (KV/SSM cache
    populated, logits returned for the last position).  Micro-batches split
    the batch dimension; the per-stage cache is [Lps, B_loc, ...] and each
    tick dynamic-slices its micro-batch rows.  Cache ``len`` counters are
    frozen during the tick scan (every micro-batch writes at the same
    offset) and advanced once at the end.
    """
    if plan.virtual != 1:
        raise NotImplementedError(
            "pipelined serving does not support interleaved (virtual>1) "
            "plans; decode is latency-bound, not flush-bubble-bound — "
            "use plan_stages(cfg, virtual=1) for serving")
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S})"
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"])
    M_ = pcfg.n_microbatches
    fsdp_dims = ST.fsdp_scan_dims(specs) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1

    cache_shapes = jax.eval_shape(
        functools.partial(init_pipeline_cache, cfg, plan, global_batch,
                          max_len, dtype=cache_dtype, enc_len=enc_len))
    cspecs = cache_specs(cfg, cache_shapes, batch_axes,
                         b_sharded=batch_sharded, stage_axis=stage_ax)
    batch_spec = dict(tokens=P(batch_axes if batch_sharded else None, None))
    if cfg.family == "vlm":
        batch_spec["pos3"] = P(None, batch_axes if batch_sharded else None, None)

    def sharded_decode(params, cache, batch):
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])
        cache_local = jax.tree.map(lambda a: a[0], cache)

        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)           # [B_loc,q,d]
        B_loc = x_all.shape[0]
        assert B_loc % M_ == 0
        mb = B_loc // M_
        cur_len = jnp.asarray(M._cache_len(cache_local), jnp.int32)
        pos1 = cur_len + jnp.arange(q_len, dtype=jnp.int32)
        if cfg.family == "audio":
            x_all = x_all + M.sinusoid_pos(
                jnp.broadcast_to(pos1[None], (B_loc, q_len)),
                cfg.d_model, x_all.dtype)
        inj = x_all.reshape(M_, mb, q_len, -1)
        if cfg.family == "audio":
            # decode consumes the cross K/V cache; h_enc is vestigial
            inj = dict(h_dec=inj,
                       h_enc=jnp.zeros((M_, mb, 1, cfg.d_model), x_all.dtype))
        pos = jnp.broadcast_to(pos1[None], (mb, q_len))
        pos3 = None
        if batch.get("pos3") is not None:
            pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, q_len), 1, 0)

        def tick(carry, t):
            x_cur, cache_l, outbuf = carry
            # micro-batch this stage works on at tick t
            m_idx = t - stage_idx
            valid = (m_idx >= 0) & (m_idx < M_)
            mc = jnp.clip(m_idx, 0, M_ - 1)
            x_in = jax.tree.map(
                lambda q, c: jnp.where(stage_idx == 0,
                                       q[jnp.clip(t, 0, M_ - 1)], c),
                inj, x_cur)
            # slice this micro-batch's cache rows
            c_mb = jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(a, mc * mb, mb, 1)
                if a.ndim >= 2 else a, cache_l)
            p3 = None if pos3 is None else pos3[mc]

            def _run(args):
                x_in, c_mb = args
                y, _, c_new = apply_stage(
                    cfg, lp_local, smeta_local, x_in, pos=pos, pos3=p3,
                    cache=c_mb, tp_axis="tensor", tp_index=tp_index,
                    dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                    fsdp_axis="data" if cfg.fsdp else None,
                    fsdp_dims=fsdp_dims, remat="none",
                    unroll=pcfg.inner_unroll)
                return y, c_new

            if pcfg.gate_ticks:
                # validity is uniform across (data, tensor) for a fixed
                # (stage, tick), so collectives inside the branch are safe
                y, c_new = lax.cond(valid, _run, lambda a: a, (x_in, c_mb))
            else:
                y, c_new = _run((x_in, c_mb))
            # write back only when this tick was valid for this stage;
            # freeze 'len' counters (all micro-batches share the offset)
            c_new = jax.tree.map(
                lambda new, old: jnp.where(valid, new, old), c_new, c_mb)
            c_new = _restore_len(c_new, c_mb)
            cache_l = jax.tree.map(
                lambda full, blk: lax.dynamic_update_slice_in_dim(
                    full, blk.astype(full.dtype), mc * mb, 1)
                if full.ndim >= 2 else blk, cache_l, c_new)
            out_t = t - (S - 1)
            oc = jnp.clip(out_t, 0, M_ - 1)
            curo = lax.dynamic_index_in_dim(outbuf, oc, 0, keepdims=False)
            wr = jnp.where((out_t >= 0) & (stage_idx == S - 1),
                           _hidden_of(y)[:, -1:], curo)
            outbuf = lax.dynamic_update_index_in_dim(outbuf, wr, oc, 0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = jax.tree.map(lambda a: lax.ppermute(a, stage_ax, perm), y)
            return (x_next, cache_l, outbuf), None

        x0 = jax.tree.map(lambda q: jnp.zeros_like(q[0]), inj)
        outbuf0 = jnp.zeros((M_, mb, 1, cfg.d_model), x_all.dtype)
        (_, cache_local, outbuf), _ = lax.scan(
            tick, (x0, cache_local, outbuf0), jnp.arange(M_ + S - 1),
            unroll=pcfg.tick_scan_unroll)
        cache_local = _advance_len(cache_local, q_len)

        h = LYR.rms_norm(outbuf.reshape(B_loc, 1, -1), params["final_norm"],
                         cfg.norm_eps)
        table = params.get("head", params["embed"])
        logits = (h @ table.T).astype(jnp.float32)
        # broadcast real logits from the last stage to every stage
        on_last = (stage_idx == S - 1).astype(logits.dtype)
        logits = lax.psum(logits * on_last, stage_ax)
        new_cache = jax.tree.map(lambda a: a[None], cache_local)
        return logits, new_cache

    fn = shard_map(
        sharded_decode, mesh=mesh,
        in_specs=(specs, cspecs, batch_spec),
        out_specs=(P(batch_axes if batch_sharded else None, None, "tensor"),
                   cspecs),
        check_rep=False)
    return jax.jit(fn, donate_argnums=(1,)), specs, cspecs, cache_shapes
