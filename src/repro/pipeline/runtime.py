"""BaPipe distributed runtime: intra-batch pipeline parallelism as
``shard_map`` + ``lax.scan`` + ``lax.ppermute`` on a
("pod",) ("data", "stage", "tensor") mesh.

Execution model (per device, SPMD):

* layer parameters arrive stacked ``[1, Lps, ...]`` (stage-sharded);
* TRAINING runs ONE tick scan over the schedule's full mixed F/B(/W) op
  table: backward ops are first-class ticks.  ``make_train_step`` builds
  the op table with the schedule-plan IR (:mod:`repro.core.schedplan`)
  and compiles it with :func:`repro.core.schedplan.lower_to_ticks` into
  per-device per-tick lookup arrays (op kind, micro-batch, chunk,
  stash/inbox slots); the scan body ``lax.switch``es on the op kind.
  There is NO autodiff of the scan — gradients are assembled manually:

  - an F tick applies the stage block and saves its *input* into a
    statically allocated residual stash (slot count == the schedule's
    peak-live row, by construction);
  - a B tick re-runs the stage forward from the stashed residual under
    ``jax.vjp`` and applies it to the cotangent arriving on the
    *backward* ppermute ring (stage s -> s-1), accumulating layer grads
    and sending the input-cotangent upstream.  On the last virtual stage
    the cotangent is seeded by that micro-batch's loss head
    (final-norm + logits + xent), computed inside the tick;
  - a W tick (zero-bubble schedules) re-runs the forward once more and
    applies the stashed cotangent to the *parameters* only — the
    input-gradient B tick earlier propagated the error without paying
    for weight grads on the critical path;
  - the two rings shift every tick; arrivals the consuming op is not
    ready for are parked in statically allocated inbox slots.

* stage 0 injects micro-batches (and collects the injection cotangents
  that feed the embedding backward); the per-micro-batch losses are
  summed into the same global scalar as before and ``psum``-broadcast;
* gradients are then ``psum``'d over exactly the axes each leaf is
  replicated on (data/pod for everything; +stage for embed/head/norm) —
  the paper's "orthogonal to data parallelism", literally.  Under
  ``runtime='stream'`` the DATA-axis share of that sync is instead
  compiled INTO the schedule (``PipelineConfig.grad_sync``): the plan
  carries one AR op per (device, chunk) gradient bucket, scheduled into
  the drain right after the bucket's last B/W tick (stage N-1 retires
  first and syncs earliest, stage 0 last), and the scan executes each AR
  slot as a chunked ``psum_scatter`` + ``all_gather`` over ``data`` —
  retired buckets sync while later micro-batches are still in B/W, so
  the all-reduce hides in the pipeline bubble.  The trailing psum then
  skips ``data`` for the layer grads; embed/head/norm grads and
  fsdp-sharded leaves keep the full trailing sync.

``PipelineConfig.schedule`` selects the executed order — ``gpipe``,
``1f1b`` / ``dapple`` (early backward), ``zb-h1`` (zero-bubble split
backward), ``1f1b-interleaved`` (streaming chunk passes, the ``auto``
default for V > 1) or ``1f1b-interleaved-memlean`` (Megatron groups of
S; its every ring return is consumed the tick it arrives).  Interleaved
1F1B (plan.virtual = V > 1) stacks parameters ``[1, V, Lc, ...]`` — V
non-contiguous layer chunks per device, chunk v of device n being
virtual stage v*S + n — and both rings loop the daisy chain V times.

Because B ticks recompute the stage from its stashed input, the residual
footprint IS the schedule's features-memory row (1F1B's S - n instead of
GPipe's M) — ``remat='stage'`` semantics are structural now.
``remat='full'`` additionally rematerialises per layer inside the B-tick
recompute.

SERVING (``make_serve_step``) still runs the forward-only lowering
(:func:`repro.core.schedplan.lower_to_ring`): one tick scan over
``M*V + S - 1`` forward elements with the stage-0 return buffer rules the
ring lowering emits.  Micro-batch positions (``pos3``, VLM M-RoPE) ride
the serve ring alongside the activation; the train scan instead indexes
the per-micro-batch position table by the tick's micro-batch, so stage s
always applies the positions of the micro-batch it actually holds.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ArchConfig
from repro.core import schedplan as SP
from repro.models import layers as LYR
from repro.models import model as M
from repro.pipeline import stage as ST


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_microbatches: int = 4
    schedule: str = "auto"          # schedplan name: auto | gpipe | 1f1b |
                                    # dapple | zb-h1 | zb-h2 | zb-auto |
                                    # 1f1b-interleaved |
                                    # 1f1b-interleaved-memlean
    mem_limit: int = 0              # zb-auto peak-live cap (resident
                                    # micro-batch residuals per device);
                                    # 0 = unbounded (fully bubble-free
                                    # order, M-deep residual stash)
    remat: str = "stage"            # none | stage | stage_save_moe | full.
                                    # Training recomputes each stage from
                                    # its stashed input at the B tick, so
                                    # 'none'/'stage'/'stage_save_moe' all
                                    # behave as structural stage-remat
                                    # (MoE all_to_alls DO re-run in the
                                    # B-tick recompute); 'full' adds
                                    # per-layer remat inside it
    runtime: str = "ticks"          # ticks | stream.  'ticks' replays the
                                    # tick grid with both rings shifting
                                    # every tick (two full-pytree ppermutes
                                    # per tick, even on idle/W ticks);
                                    # 'stream' executes the compiled
                                    # instruction streams
                                    # (schedplan.lower_to_instructions):
                                    # ring collectives fire ONLY at slots
                                    # where some device SENDs, so ops take
                                    # their actual durations and W/idle
                                    # slots run communication-free
    grad_sync: str = "auto"         # auto | end | overlap | 2bw.
                                    # 'overlap' compiles the data-axis
                                    # gradient all-reduce into the
                                    # schedule as AR bucket ops executed
                                    # inside the tick scan (stream
                                    # runtime only — the AR slots ride
                                    # the instruction stream); 'end'
                                    # keeps the trailing full-pytree
                                    # psum; 'auto' overlaps iff
                                    # runtime='stream'.  '2bw' is
                                    # PipeDream-2BW double-buffered
                                    # weights: step k's (fully synced)
                                    # gradients are applied at step k+1,
                                    # so the collective has a whole step
                                    # of slack — sync-free steady state
                                    # at a pinned one-step staleness
                                    # (both runtimes; needs an optimizer
                                    # and the 2bw-wrapped opt state,
                                    # :func:`init_2bw_state`)
    ar_groups: int = 1              # grad_sync='overlap': split each
                                    # (device, chunk) gradient bucket
                                    # into this many per-layer-group AR
                                    # sub-buckets (layers per chunk must
                                    # divide evenly); 1 = one bucket
    pod_role: str = "data"          # data | stage  (stage = pipeline over DCN)
    unroll: bool = False            # fully unroll ALL scans (roofline mode)
    gate_ticks: bool = False        # serve: lax.cond-skip invalid ticks so
                                    # devices neither compute nor stream
                                    # weights during fill/drain (real TPUs
                                    # take one conditional branch)
    tick_unroll: int = 0            # >0: unroll factor for the tick scan
                                    # only (two-point roofline differencing);
                                    # inner scans are then fully unrolled

    @property
    def inner_unroll(self) -> bool:
        return self.unroll or self.tick_unroll > 0

    @property
    def tick_scan_unroll(self):
        if self.unroll:
            return True
        return self.tick_unroll if self.tick_unroll > 0 else 1


def _batch_axes(mesh: Mesh, pcfg: PipelineConfig) -> tuple[str, ...]:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if pcfg.pod_role == "stage":
        axes = tuple(a for a in axes if a != "pod")
    return axes


def _stage_axes(mesh: Mesh, pcfg: PipelineConfig):
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        return ("pod", "stage")
    return "stage"


def _n_stages(mesh: Mesh, pcfg: PipelineConfig) -> int:
    s = mesh.shape["stage"]
    if pcfg.pod_role == "stage" and "pod" in mesh.axis_names:
        s *= mesh.shape["pod"]
    return s


# ---------------------------------------------------------------------------
# Per-stage block apply (scan over the stage's layers).
# ---------------------------------------------------------------------------

def _gather_fsdp(lp: dict, fsdp_dims: dict, axis: str) -> dict:
    def g(path, leaf):
        name = getattr(path[-1], "key", None)
        dim = fsdp_dims.get(name)
        if dim is None:
            return leaf
        return lax.all_gather(leaf, axis, axis=dim, tiled=True)
    return jax.tree_util.tree_map_with_path(g, lp)


def apply_stage(cfg: ArchConfig, stage_params, stage_meta, x, *,
                pos, pos3=None, cache=None, tp_axis=None, tp_index=None,
                dp_axis=None, dp_index=None, n_dp=1,
                fsdp_axis=None, fsdp_dims=None, remat="stage",
                unroll=False):
    """Scan this stage's Lps layers over activation pytree ``x``.

    ``x`` is the raw hidden state [mb,T,d], or for audio a dict
    {h_enc, h_dec}.  Padded (inactive) layer slots pass through unchanged.
    Returns (x', aux, new_cache)."""

    def layer_body(carry, inp):
        xc, aux = carry
        lp, ml, cl = inp
        if fsdp_axis is not None and fsdp_dims:
            lp = _gather_fsdp(lp, fsdp_dims, fsdp_axis)
        blk_x = dict(h_enc=xc["h_enc"], h_dec=xc["h_dec"]) \
            if isinstance(xc, dict) else xc
        y, new_cl, a = M.block_apply(cfg, lp, blk_x, ml, pos=pos, pos3=pos3,
                                     cache_l=cl, tp_axis=tp_axis,
                                     tp_index=tp_index, dp_axis=dp_axis,
                                     dp_index=dp_index, n_dp=n_dp)
        act = ml["active"]
        y = jax.tree.map(lambda new, old: jnp.where(act, new, old), y, blk_x)
        if new_cl is not None:
            new_cl = jax.tree.map(lambda new, old: jnp.where(act, new, old),
                                  new_cl, cl)
        return (y, aux + jnp.where(act, a, 0.0)), new_cl

    body = jax.checkpoint(layer_body) if remat == "full" else layer_body
    (x, aux), new_cache = lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params, stage_meta, cache),
        unroll=unroll)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Micro-batch preparation (embedding etc., data-parallel, outside the pipe).
# ---------------------------------------------------------------------------

def _prepare_microbatches(cfg: ArchConfig, params, batch, M_: int, tp_index):
    """Returns (inj [M, ...] pytree of per-microbatch injected carries,
    pos [mb,T], pos3 [M,3,mb,T] or None)."""
    if cfg.family == "vlm" and "embeds" in batch:
        x_all = batch["embeds"]
    else:
        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)
    B_loc, T = x_all.shape[0], x_all.shape[1]
    assert B_loc % M_ == 0, f"local batch {B_loc} not divisible by M={M_}"
    mb = B_loc // M_
    pos = jnp.broadcast_to(jnp.arange(T)[None], (mb, T))
    if cfg.family == "audio":
        x_all = x_all + M.sinusoid_pos(
            jnp.broadcast_to(jnp.arange(T)[None], (B_loc, T)), cfg.d_model,
            x_all.dtype)
        frames = batch["frames"].astype(x_all.dtype)
        Sf = frames.shape[1]
        enc_pos = jnp.broadcast_to(jnp.arange(Sf)[None], (B_loc, Sf))
        h_enc = frames + M.sinusoid_pos(enc_pos, cfg.d_model, x_all.dtype)
        inj = dict(h_dec=x_all.reshape(M_, mb, T, -1),
                   h_enc=h_enc.reshape(M_, mb, Sf, -1))
    else:
        inj = x_all.reshape(M_, mb, T, -1)
    pos3 = None
    if batch.get("pos3") is not None:
        pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, T), 1, 0)
    return inj, pos, pos3, mb, T


def _hidden_of(y):
    return y["h_dec"] if isinstance(y, dict) else y


def _ring_tables(lowering: SP.RingLowering) -> dict:
    """The lowering's per-element lookup arrays as device constants: the
    per-tick (micro-batch, chunk, fresh/direct/park/collect) assignment of
    the compiled schedule, indexed by ``e = tick - stage`` in the scan."""
    return dict(
        m=jnp.asarray(lowering.m_of_e, jnp.int32),
        v=jnp.asarray(lowering.v_of_e, jnp.int32),
        fresh=jnp.asarray(lowering.fresh, bool),
        direct=jnp.asarray(lowering.direct, bool),
        park=jnp.asarray(lowering.park, bool),
        collect=jnp.asarray(lowering.collect, bool))


def _tick_tables(lo: SP.TickLowering) -> dict:
    """The tick lowering's per-device per-tick lookup tables as flat
    device constants, indexed by ``stage_idx * n_ticks + t``: op kind,
    micro-batch, chunk, and the stash/inbox slots of the mixed F/B(/W)
    schedule."""
    def flat(rows, dt=jnp.int32):
        return jnp.asarray([x for row in rows for x in row], dt)
    return dict(
        kind=flat(lo.kind), m=flat(lo.m), v=flat(lo.v),
        xw=flat(lo.xw), xr=flat(lo.xr),
        fsrc=flat(lo.fsrc), fr=flat(lo.fr), fpark=flat(lo.fpark),
        bsrc=flat(lo.bsrc), br=flat(lo.br), bpark=flat(lo.bpark),
        cw=flat(lo.cw), cr=flat(lo.cr), dinj=flat(lo.dinj, bool))


def _stream_tables(instr: SP.InstrLowering) -> dict:
    """The instruction lowering's tables: the tick tables plus the two
    global per-slot comm gates — ``fsend[t]``/``bsend[t]`` is True iff
    some device SENDs on that ring at slot ``t``.  Both are functions of
    the slot counter alone (identical on every device), so the gated
    ring collectives stay uniform across the mesh."""
    return dict(_tick_tables(instr.ticks),
                fsend=jnp.asarray(instr.fsend, bool),
                bsend=jnp.asarray(instr.bsend, bool),
                aron=jnp.asarray(instr.arsync, bool))


def _buf_read(buf, slot):
    """Read pytree slot ``buf[slot]`` of a leading-dim buffer pytree."""
    return jax.tree.map(
        lambda a: lax.dynamic_index_in_dim(a, slot, 0, keepdims=False), buf)


def _buf_write(buf, slot, val, do):
    """Write ``val`` into pytree slot ``buf[slot]`` where ``do`` (else
    keep the old slot content)."""
    def w(a, x):
        old = lax.dynamic_index_in_dim(a, slot, 0, keepdims=False)
        return lax.dynamic_update_index_in_dim(
            a, jnp.where(do, x, old), slot, 0)
    return jax.tree.map(w, buf, val)


def _at(table: jnp.ndarray, idx):
    return lax.dynamic_index_in_dim(table, idx, 0, keepdims=False)


def _shard_retbuf(cfg: ArchConfig, S: int, stage_ax) -> bool:
    """The stage-0 return buffer can be feature-sharded over the stage
    axis: requires a single plain axis name (pod_role='stage' fuses two
    axes — psum_scatter's tiled layout wants one) and a feature dim that
    splits evenly.  Every injected leaf's last dim is ``d_model``."""
    return isinstance(stage_ax, str) and S > 1 and cfg.d_model % S == 0


def _retbuf_init(inj, S: int, sharded: bool):
    """Zero-initialised stage-0 return buffer matching ``inj``'s [M, ...]
    layout.  Unsharded it is a FULL copy of ``inj`` on every device (the
    scan carry is SPMD-uniform, so write-masking to stage 0 does not
    shrink it); sharded each device holds 1/S of the feature dim and the
    buffer is reassembled by ``all_gather`` only at the ticks stage 0
    actually reads a parked return."""
    if not sharded:
        return jax.tree.map(jnp.zeros_like, inj)
    return jax.tree.map(
        lambda q: jnp.zeros(q.shape[:-1] + (q.shape[-1] // S,), q.dtype),
        inj)


def _ring_ingest(tab: dict, MV: int, S: int, stage_idx, t, inj, x_cur,
                 retbuf, *, stage_ax=None, sharded: bool = False):
    """Stage-0 ring ingestion for one tick of the compiled schedule: park
    the arriving ring return (when the schedule buffers; stage 0 only),
    then select this tick's stage-0 source — fresh injection (chunk-0
    pass), the ring return straight off the ppermute carry (``direct``),
    or the parked return.  ``retbuf`` is None for schedules that consume
    every return the tick it arrives.

    When ``sharded``, the return buffer holds 1/S of every feature dim
    per device: parking scatters stage 0's arrival over the stage axis
    (``psum_scatter`` of a stage-0-masked contribution), reading gathers
    it back.  Both collectives are gated by predicates that depend on
    the tick alone — uniform across the mesh, so the branches are safe
    (cf. ``gate_ticks``) and non-park ticks pay nothing.
    Returns (retbuf, x_in)."""
    if retbuf is not None:
        e_arr = t - S
        eacl = jnp.clip(e_arr, 0, MV - 1)
        want_park = (e_arr >= 0) & _at(tab["park"], eacl)
        slot = _at(tab["m"], eacl)
        if sharded:
            def park_scatter(rb):
                def park1(rb_l, c):
                    contrib = jnp.where(stage_idx == 0, c,
                                        jnp.zeros_like(c))
                    sh = lax.psum_scatter(contrib, stage_ax,
                                          scatter_dimension=c.ndim - 1,
                                          tiled=True)
                    return lax.dynamic_update_index_in_dim(rb_l, sh,
                                                           slot, 0)
                return jax.tree.map(park1, rb, x_cur)

            retbuf = lax.cond(want_park, park_scatter, lambda rb: rb,
                              retbuf)
        else:
            do_park = want_park & (stage_idx == 0)

            def park(rb, c):
                old = lax.dynamic_index_in_dim(rb, slot, 0, keepdims=False)
                return lax.dynamic_update_index_in_dim(
                    rb, jnp.where(do_park, c, old), slot, 0)

            retbuf = jax.tree.map(park, retbuf, x_cur)
    e0 = jnp.clip(t, 0, MV - 1)
    m0 = _at(tab["m"], e0)
    is_fresh = _at(tab["fresh"], e0)
    if retbuf is not None:
        take_direct = _at(tab["direct"], e0)
        if sharded:
            def read_gather(rb):
                def gather1(rb_l):
                    sl = lax.dynamic_index_in_dim(rb_l, m0, 0,
                                                  keepdims=False)
                    return lax.all_gather(sl, stage_ax, axis=sl.ndim - 1,
                                          tiled=True)
                return jax.tree.map(gather1, rb)

            parked = lax.cond(
                ~is_fresh & ~take_direct, read_gather,
                lambda rb: jax.tree.map(jnp.zeros_like, x_cur), retbuf)
            src = jax.tree.map(
                lambda q, pk, c: jnp.where(
                    is_fresh,
                    lax.dynamic_index_in_dim(q, m0, 0, keepdims=False),
                    jnp.where(take_direct, c, pk)),
                inj, parked, x_cur)
        else:
            src = jax.tree.map(
                lambda q, rb, c: jnp.where(
                    is_fresh,
                    lax.dynamic_index_in_dim(q, m0, 0, keepdims=False),
                    jnp.where(take_direct, c,
                              lax.dynamic_index_in_dim(rb, m0, 0,
                                                       keepdims=False))),
                inj, retbuf, x_cur)
    else:
        src = jax.tree.map(
            lambda q, c: jnp.where(
                is_fresh,
                lax.dynamic_index_in_dim(q, m0, 0, keepdims=False),
                c),
            inj, x_cur)
    x_in = jax.tree.map(
        lambda s_, c: jnp.where(stage_idx == 0, s_, c), src, x_cur)
    return retbuf, x_in


# ---------------------------------------------------------------------------
# Training step factory.
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, optimizer=None,
                    param_dtype=jnp.float32):
    """Build the jitted pipeline train step.

    Returns (step_fn, specs): without an optimizer ``step_fn(params, batch)
    -> (loss, grads)``; with one ``step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)``."""
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S}); " \
        f"with pod_role='stage' build the plan with n_stages=pod*stages"
    V = plan.virtual
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"], virtual=V)
    M_ = pcfg.n_microbatches
    # compile the schedule's FULL mixed F/B(/W) op table and lower it to
    # per-device per-tick lookup arrays: backward ops are first-class
    # ticks, executed by the same scan as the forwards
    if pcfg.runtime not in ("ticks", "stream"):
        raise ValueError(f"unknown runtime {pcfg.runtime!r}: "
                         f"expected ticks | stream")
    if pcfg.grad_sync not in ("auto", "end", "overlap", "2bw"):
        raise ValueError(f"unknown grad_sync {pcfg.grad_sync!r}: "
                         f"expected auto | end | overlap | 2bw")
    if pcfg.grad_sync == "overlap" and pcfg.runtime != "stream":
        raise ValueError("grad_sync='overlap' requires runtime='stream' "
                         "(the tick replay has no AR slots)")
    two_bw = pcfg.grad_sync == "2bw"
    if two_bw and optimizer is None:
        raise ValueError("grad_sync='2bw' double-buffers the weight "
                         "update and needs an optimizer")
    if pcfg.ar_groups < 1:
        raise ValueError(f"ar_groups must be >= 1, got {pcfg.ar_groups}")
    if pcfg.ar_groups > 1 and not (
            pcfg.runtime == "stream"
            and pcfg.grad_sync in ("auto", "overlap")):
        raise ValueError("ar_groups > 1 splits the OVERLAPPED AR buckets; "
                         "it requires runtime='stream' with "
                         "grad_sync='overlap' (or 'auto')")
    dp_size = mesh.shape.get("data", 1)
    # layer-grad leaves the in-scan AR covers: replicated over data
    # (fsdp-sharded leaves keep the trailing sync)
    ar_mask = jax.tree.map(
        lambda s: "data" in ST.grad_sync_axes(s, mesh_axes),
        specs["layers"])
    overlap_sync = (pcfg.grad_sync == "overlap"
                    or (pcfg.grad_sync == "auto"
                        and pcfg.runtime == "stream"))
    overlap_sync = (overlap_sync and dp_size > 1
                    and any(jax.tree.leaves(ar_mask)))
    ar_groups = pcfg.ar_groups if overlap_sync else 1
    sched = SP.resolve_ring_schedule(pcfg.schedule, V)
    ml = (pcfg.mem_limit or None) if sched == "zb-auto" else None
    plan_ir = SP.build_schedule(sched, M_, S, V, mem_limit=ml,
                                grad_sync=ar_groups if overlap_sync
                                else False)
    instr = (SP.lower_to_instructions(plan_ir)
             if pcfg.runtime == "stream" else None)
    lowering = instr.ticks if instr else SP.lower_to_ticks(plan_ir)
    has_w = lowering.has_w
    if pcfg.remat not in ("none", "stage", "stage_save_moe", "full"):
        raise ValueError(
            f"unknown remat {pcfg.remat!r}: expected none | stage | "
            f"stage_save_moe | full (the first three are equivalent under "
            f"first-class backward ticks — B recomputes the stage from "
            f"its stashed input)")
    fsdp_dims = ST.fsdp_scan_dims(specs, virtual=V) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1
    n_batch_shards = math.prod(mesh.shape[a] for a in batch_axes) or 1

    def batch_spec_for(keys):
        spec = {}
        for k in keys:
            if k in ("tokens", "labels"):
                spec[k] = P(batch_axes, None)
            elif k in ("embeds", "frames"):
                spec[k] = P(batch_axes, None, None)
            elif k == "pos3":
                spec[k] = P(None, batch_axes, None)
        return spec

    tp_size = mesh.shape["tensor"]

    def global_loss_and_grads(params, batch):
        """One pass over the compiled mixed F/B(/W) tick table, producing
        this device's LOCAL loss term and its gradient contributions —
        no autodiff of the scan; every backward is an explicit tick."""
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])

        # micro-batch preparation under vjp: the injection cotangents the
        # scan accumulates (stage 0's chunk-0 B ticks) drive the
        # embedding backward after the scan
        def prep(embed):
            inj, pos, pos3, mb, T = _prepare_microbatches(
                cfg, dict(params, embed=embed), batch, M_, tp_index)
            return inj, (pos, pos3, mb, T)

        inj, prep_vjp, (pos, pos3, mb, T) = jax.vjp(
            prep, params["embed"], has_aux=True)
        labels_mb = batch["labels"].reshape(M_, mb, -1)
        fn_p = params["final_norm"]
        head_p = params.get("head", params["embed"])
        tab = _stream_tables(instr) if instr else _tick_tables(lowering)
        nT = lowering.n_ticks
        # d(global loss)/d(per-micro-batch ce) == d/d(per-op aux): the
        # seed every B tick's vjp is driven by
        ct_scale = jnp.float32(1.0 / (M_ * n_batch_shards * tp_size))

        def stage_f(lp_t, sm_t, x, m):
            """Forward of one stage chunk on micro-batch m: the function
            every F tick applies and every B/W tick re-runs under vjp
            from the stashed residual (remat='stage' structurally)."""
            p3 = None if pos3 is None else lax.dynamic_index_in_dim(
                pos3, m, 0, keepdims=False)
            y, a, _ = apply_stage(
                cfg, lp_t, sm_t, x, pos=pos, pos3=p3, cache=None,
                tp_axis="tensor", tp_index=tp_index,
                dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                fsdp_axis="data" if cfg.fsdp else None,
                fsdp_dims=fsdp_dims,
                remat="full" if pcfg.remat == "full" else "none",
                unroll=pcfg.inner_unroll)
            return y, a

        def head_loss(fn_param, head_param, y, m):
            """Per-micro-batch loss head (final norm + logits + xent):
            seeds the backward of the last virtual stage."""
            h = LYR.rms_norm(_hidden_of(y), fn_param, cfg.norm_eps)
            labels_m = lax.dynamic_index_in_dim(labels_mb, m, 0,
                                                keepdims=False)
            return M.logits_and_xent(cfg,
                                     {"head": head_param,
                                      "embed": head_param}, h, labels_m,
                                     "tensor", tp_index)

        zero_pay = jax.tree.map(lambda q: jnp.zeros_like(q[0]), inj)

        def buf0(k):
            if not k:
                return None
            return jax.tree.map(
                lambda z: jnp.zeros((k,) + z.shape, z.dtype), zero_pay)

        carry0 = dict(
            fwd=zero_pay, bwd=zero_pay,
            xs=buf0(lowering.n_x),      # residual stash == peak-live row
            fin=buf0(lowering.n_f),     # parked forward arrivals
            bin=buf0(lowering.n_b),     # parked backward arrivals
            ct=buf0(lowering.n_c),      # zb: cotangents alive B -> W
            dinj=jax.tree.map(jnp.zeros_like, inj),
            dlp=jax.tree.map(jnp.zeros_like, lp_local),
            dfn=jnp.zeros_like(fn_p), dhd=jnp.zeros_like(head_p),
            ce=jnp.zeros((), jnp.float32), aux=jnp.zeros((), jnp.float32))

        def tick(carry, t):
            idx = stage_idx * nT + t
            g = lambda name: _at(tab[name], idx)
            m_t, v_t = g("m"), g("v")
            # park this tick's ring arrivals the consumer isn't ready for
            if carry["fin"] is not None:
                sl = g("fpark")
                carry = dict(carry, fin=_buf_write(
                    carry["fin"], jnp.maximum(sl, 0), carry["fwd"], sl >= 0))
            if carry["bin"] is not None:
                sl = g("bpark")
                carry = dict(carry, bin=_buf_write(
                    carry["bin"], jnp.maximum(sl, 0), carry["bwd"], sl >= 0))
            if V > 1:
                pick = lambda a: lax.dynamic_index_in_dim(a, v_t, 0,
                                                          keepdims=False)
                lp_t = jax.tree.map(pick, lp_local)
                sm_t = jax.tree.map(pick, smeta_local)
            else:
                lp_t, sm_t = lp_local, smeta_local

            def read_res(c):
                return _buf_read(c["xs"], jnp.maximum(g("xr"), 0))

            def acc_dlp(acc, dlp):
                if V > 1:
                    def upd(a, d):
                        cur = lax.dynamic_index_in_dim(a, v_t, 0,
                                                       keepdims=False)
                        return lax.dynamic_update_index_in_dim(
                            a, cur + d, v_t, 0)
                    return jax.tree.map(upd, acc, dlp)
                return jax.tree.map(lambda a, d: a + d, acc, dlp)

            def idle_fn(c):
                return c

            def f_fn(c):
                fsrc = g("fsrc")
                fresh = jax.tree.map(
                    lambda q: lax.dynamic_index_in_dim(q, m_t, 0,
                                                       keepdims=False), inj)
                if c["fin"] is not None:
                    inbox = _buf_read(c["fin"], jnp.maximum(g("fr"), 0))
                    x_in = jax.tree.map(
                        lambda fq, cq, bq: jnp.where(
                            fsrc == 0, fq, jnp.where(fsrc == 2, bq, cq)),
                        fresh, c["fwd"], inbox)
                else:
                    x_in = jax.tree.map(
                        lambda fq, cq: jnp.where(fsrc == 0, fq, cq),
                        fresh, c["fwd"])
                y, a = stage_f(lp_t, sm_t, x_in, m_t)
                return dict(c, fwd=y, aux=c["aux"] + a,
                            xs=_buf_write(c["xs"], g("xw"), x_in, True))

            def b_ct(c):
                if c["bin"] is not None:
                    inbox = _buf_read(c["bin"], jnp.maximum(g("br"), 0))
                    return jax.tree.map(
                        lambda cq, bq: jnp.where(g("bsrc") == 2, bq, cq),
                        c["bwd"], inbox)
                return c["bwd"]

            def b_ring_fn(c):
                x_res = read_res(c)
                ctan = b_ct(c)
                if has_w:
                    # zb: input-gradient only; stash the cotangent for W
                    _, vjp = jax.vjp(
                        lambda xx: stage_f(lp_t, sm_t, xx, m_t), x_res)
                    (dx,) = vjp((ctan, ct_scale))
                    c = dict(c, ct=_buf_write(
                        c["ct"], jnp.maximum(g("cw"), 0), ctan,
                        g("cw") >= 0))
                else:
                    _, vjp = jax.vjp(
                        lambda lp, xx: stage_f(lp, sm_t, xx, m_t),
                        lp_t, x_res)
                    dlp, dx = vjp((ctan, ct_scale))
                    c = dict(c, dlp=acc_dlp(c["dlp"], dlp))
                return dict(c, bwd=dx, dinj=_buf_write(
                    c["dinj"], m_t, dx, g("dinj")))

            def b_seed_fn(c):
                x_res = read_res(c)
                if has_w:
                    # zb: vjp the stage (input grad) and the loss head
                    # separately — the head's y-cotangent is stashed so
                    # the seed's W tick is an ordinary w_fn, and the
                    # head/final-norm grads (outside the pipeline
                    # stages) land here without a second head pass
                    (y, _), svjp = jax.vjp(
                        lambda xx: stage_f(lp_t, sm_t, xx, m_t), x_res)
                    ce_m, hvjp = jax.vjp(
                        lambda fnp, hdp, yy: head_loss(fnp, hdp, yy, m_t),
                        fn_p, head_p, y)
                    dfn_d, dhd_d, dy = hvjp(ct_scale)
                    (dx,) = svjp((dy, ct_scale))
                    c = dict(c, dfn=c["dfn"] + dfn_d, dhd=c["dhd"] + dhd_d,
                             ct=_buf_write(c["ct"], jnp.maximum(g("cw"), 0),
                                           dy, g("cw") >= 0))
                else:
                    def fl(lp, fnp, hdp, xx):
                        y, a = stage_f(lp, sm_t, xx, m_t)
                        return head_loss(fnp, hdp, y, m_t), a
                    (ce_m, _), vjp = jax.vjp(fl, lp_t, fn_p, head_p, x_res)
                    dlp, dfn_d, dhd_d, dx = vjp((ct_scale, ct_scale))
                    c = dict(c, dlp=acc_dlp(c["dlp"], dlp),
                             dfn=c["dfn"] + dfn_d, dhd=c["dhd"] + dhd_d)
                return dict(c, bwd=dx, ce=c["ce"] + ce_m,
                            dinj=_buf_write(c["dinj"], m_t, dx, g("dinj")))

            def w_fn(c):
                x_res = read_res(c)
                ctan = _buf_read(c["ct"], jnp.maximum(g("cr"), 0))
                _, vjp = jax.vjp(
                    lambda lp: stage_f(lp, sm_t, x_res, m_t), lp_t)
                (dlp,) = vjp((ctan, ct_scale))
                return dict(c, dlp=acc_dlp(c["dlp"], dlp))

            branches = [idle_fn, f_fn, b_ring_fn, b_seed_fn]
            if has_w:
                branches.append(w_fn)
            kind_t = g("kind")
            if plan_ir.has_grad_sync:
                # AR slots execute below, outside the switch; the
                # compute branch for them is idle
                kind_t = jnp.where(kind_t == SP.TICK_AR, SP.TICK_IDLE,
                                   kind_t)
            carry = lax.switch(jnp.clip(kind_t, 0, len(branches) - 1),
                               branches, carry)
            if plan_ir.has_grad_sync:
                n_groups = plan_ir.grad_sync_groups or 1

                def ar_fn(c):
                    """One AR slot: reduce-scatter + all-gather this
                    device's retired chunk-``v_t`` layer-grad bucket over
                    ``data``.  The gate (``aron[t]``) depends on the slot
                    counter alone, so every device enters the cond;
                    within one data group all members share a stage ->
                    identical tables -> they sync the same bucket
                    together.  Groups whose device holds no AR here
                    compute a discarded sum (masked write-back).  With
                    ``ar_groups > 1`` the AR op's ``m`` field is the
                    layer-group index: each slot syncs only rows
                    ``[g * Lc/G, (g+1) * Lc/G)`` of the chunk's grads —
                    every element still reduced exactly once, so the
                    result stays bit-equal to the one-bucket sync."""
                    arw = g("kind") == SP.TICK_AR
                    g_t = g("m")        # AR ops carry the group index
                    dlp_leaves, treedef = jax.tree.flatten(c["dlp"])
                    masks = jax.tree.leaves(ar_mask)
                    chunks = [
                        (i, lax.dynamic_index_in_dim(a, v_t, 0,
                                                     keepdims=False)
                            if V > 1 else a)
                        for i, (a, el) in enumerate(zip(dlp_leaves,
                                                        masks)) if el]
                    slices = []
                    for i, ch in chunks:
                        if n_groups > 1:
                            rows = ch.shape[0]
                            if rows % n_groups:
                                raise ValueError(
                                    f"ar_groups={n_groups} must divide "
                                    f"the {rows} layers per chunk "
                                    f"(leaf {i})")
                            rg = rows // n_groups
                            sl = lax.dynamic_slice_in_dim(
                                ch, g_t * rg, rg, 0)
                        else:
                            sl = ch
                        slices.append((i, ch, sl))
                    # pack per dtype (concat cannot mix), one RS+AG over
                    # data per dtype, unpack; dp=2's single addition per
                    # element keeps the result bit-equal to the trailing
                    # psum it replaces
                    by_dt: dict = {}
                    for i, ch, sl in slices:
                        by_dt.setdefault(sl.dtype, []).append((i, ch, sl))
                    out = dict(enumerate(dlp_leaves))
                    for dt, group in by_dt.items():
                        flat = jnp.concatenate(
                            [sl.reshape(-1) for _, _, sl in group])
                        pad = (-flat.size) % dp_size
                        if pad:
                            flat = jnp.concatenate(
                                [flat, jnp.zeros((pad,), dt)])
                        red = lax.psum_scatter(flat, "data",
                                               scatter_dimension=0,
                                               tiled=True)
                        full = lax.all_gather(red, "data", axis=0,
                                              tiled=True)
                        off = 0
                        for i, ch, sl in group:
                            new = full[off:off + sl.size].reshape(
                                sl.shape)
                            off += sl.size
                            new = jnp.where(arw, new, sl)
                            if n_groups > 1:
                                rg = ch.shape[0] // n_groups
                                new = lax.dynamic_update_slice_in_dim(
                                    ch, new, g_t * rg, 0)
                            out[i] = (lax.dynamic_update_index_in_dim(
                                dlp_leaves[i], new, v_t, 0)
                                if V > 1 else new)
                    return dict(c, dlp=jax.tree.unflatten(
                        treedef, [out[i]
                                  for i in range(len(dlp_leaves))]))

                carry = lax.cond(_at(tab["aron"], t), ar_fn,
                                 lambda c: c, carry)
            perm_f = [(i, (i + 1) % S) for i in range(S)]
            perm_b = [(i, (i - 1) % S) for i in range(S)]
            shift_f = lambda tr: jax.tree.map(
                lambda a: lax.ppermute(a, stage_ax, perm_f), tr)
            shift_b = lambda tr: jax.tree.map(
                lambda a: lax.ppermute(a, stage_ax, perm_b), tr)
            if instr is not None:
                # stream runtime: a ring shifts ONLY at slots where some
                # device SENDs on it.  Every value travels exactly one hop
                # at its producer's slot (arrival is always the next slot
                # in the compiled tables), so slots without a scheduled
                # SEND carry only dead data — skipping the collective is
                # exact, and W/idle slots run with no barrier at all.
                # The gate is a function of the slot counter alone
                # (uniform across devices), so the collective inside the
                # cond is safe (cf. the gate_ticks serve path).
                fwd = lax.cond(_at(tab["fsend"], t), shift_f,
                               lambda tr: tr, carry["fwd"])
                bwd = lax.cond(_at(tab["bsend"], t), shift_b,
                               lambda tr: tr, carry["bwd"])
            else:
                # tick runtime: both rings shift every tick
                fwd = shift_f(carry["fwd"])
                bwd = shift_b(carry["bwd"])
            return dict(carry, fwd=fwd, bwd=bwd), None

        out, _ = lax.scan(tick, carry0, jnp.arange(nT),
                          unroll=pcfg.tick_scan_unroll)
        # Per-device LOCAL term of the global loss: global = psum(local).
        # ce/aux accumulated only where the table placed the ops, so no
        # stage masking is needed; tensor replication is divided out.
        local = (out["ce"] + out["aux"]) / M_ / (n_batch_shards * tp_size)
        (d_embed,) = prep_vjp(out["dinj"])
        grads = dict(embed=d_embed,
                     layers=jax.tree.map(lambda a: a[None], out["dlp"]),
                     final_norm=out["dfn"])
        if "head" in params:
            grads["head"] = out["dhd"]
        else:
            grads["embed"] = grads["embed"] + out["dhd"]
        return local, grads

    def sharded_step(params, batch):
        local, grads = global_loss_and_grads(params, batch)
        loss = lax.psum(local, mesh_axes)

        def sync(g, s, layer):
            axes = ST.grad_sync_axes(s, mesh_axes)
            if "data" in axes:
                # the data-axis sync is its own reduction, split from
                # the other replication axes: the AR-op schedule
                # replaces exactly this psum (for layer grads) with the
                # in-scan bucket collectives, and performing the data
                # sum separately for EVERY leaf in BOTH paths keeps the
                # two programs' collective structure — and hence the
                # reduction order of the remaining axes — identical
                if not (layer and plan_ir.has_grad_sync):
                    g = lax.psum(g, "data")
                axes = tuple(a for a in axes if a != "data")
            return lax.psum(g, axes) if axes else g

        grads = {
            k: jax.tree.map(functools.partial(sync, layer=(k == "layers")),
                            grads[k], specs[k])
            for k in grads}
        return loss, grads

    _built: dict = {}

    def fn(params, batch):
        keys = tuple(sorted(batch))
        if keys not in _built:
            _built[keys] = shard_map(
                sharded_step, mesh=mesh,
                in_specs=(specs, batch_spec_for(keys)),
                out_specs=(P(), specs), check_rep=False)
        return _built[keys](params, batch)

    if optimizer is None:
        return jax.jit(fn), specs

    opt_update = optimizer.make_update(specs, mesh)

    if two_bw:
        def full_step(params, opt_state, batch):
            # PipeDream-2BW double-buffered weights: compute step k's
            # grads as usual, but APPLY the stashed step k-1 grads —
            # the pending collective result isn't consumed until the
            # next call, giving it a full step of slack (sync-free
            # steady state).  Step 0 applies its own grads (warmup:
            # nothing is pending), so the trajectory is the synchronous
            # one shifted by exactly one step from step 1 on.
            loss, grads = fn(params, batch)
            primed = opt_state["primed"]
            apply_g = jax.tree.map(
                lambda p, g: jnp.where(primed, p, g),
                opt_state["pending"], grads)
            params, inner = opt_update(params, apply_g,
                                       opt_state["inner"])
            new_state = dict(inner=inner, pending=grads,
                             primed=jnp.ones((), jnp.bool_))
            return params, new_state, dict(loss=loss)
    else:
        def full_step(params, opt_state, batch):
            loss, grads = fn(params, batch)
            params, opt_state = opt_update(params, grads, opt_state)
            return params, opt_state, dict(loss=loss)

    return jax.jit(full_step, donate_argnums=(0, 1)), specs


def init_2bw_state(opt_state, params):
    """Wrap an optimizer state for ``grad_sync='2bw'`` double-buffered
    weights: ``pending`` holds the previous step's gradients (zeros
    until the first step), ``primed`` flips True after step 0 so the
    warmup step applies its own gradients instead of the zero
    buffer."""
    return dict(inner=opt_state,
                pending=jax.tree.map(jnp.zeros_like, params),
                primed=jnp.zeros((), jnp.bool_))


def state_shardings(mesh: Mesh, specs, opt_state=None):
    """``NamedSharding`` pytrees for the training state, from the param
    specs :func:`make_train_step` returns — the ``shardings`` argument
    :func:`repro.checkpoint.restore_checkpoint` wants so a resumed
    state lands directly on the mesh in the step function's layout.

    Returns the param sharding tree alone, or — given an ``opt_state``
    skeleton — ``(param_shardings, opt_shardings)`` where any opt-state
    entry whose tree structure mirrors the params (AdamW's ``m``/``v``
    moments, SGD's momentum, the 2bw ``pending`` gradient buffer)
    inherits the param shardings; nested wrappers (the 2bw
    ``inner``/``pending``/``primed`` dict) recurse, and everything else
    (step counters, flags) is replicated."""
    param_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    if opt_state is None:
        return param_sh
    rep = NamedSharding(mesh, P())
    pstruct = jax.tree.structure(specs)

    def mirror(sub):
        if jax.tree.structure(sub) == pstruct:
            return param_sh
        if isinstance(sub, dict):
            return {k: mirror(v) for k, v in sub.items()}
        return jax.tree.map(lambda _: rep, sub)

    return param_sh, {k: mirror(v) for k, v in opt_state.items()}


# ---------------------------------------------------------------------------
# Serving: pipelined decode (and prefill).
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, cache_shapes, batch_axes, *,
                b_sharded: bool, stage_axis="stage", virtual: int = 1):
    """Stage-sharded cache specs: every leaf is [S, Lps, B, ...] — or
    [S, V, Lc, B, ...] for an interleaved (virtual > 1) plan, which shifts
    the positional dims right by one.  Attention K/V caches additionally
    shard their head dim over tensor."""
    off = 0 if virtual == 1 else 1
    def leaf(path, l):
        name = getattr(path[-1], "key", None)
        if name == "len":
            # per-slot offsets [S, (V,) Lc, B]: trailing axis is the slot
            spec = [stage_axis] + [None] * (l.ndim - 1)
            if b_sharded and l.ndim >= 3:
                spec[-1] = batch_axes
            return P(*spec)
        spec = [stage_axis, None] + [None] * (l.ndim - 2)
        if b_sharded and l.ndim >= 3 + off:
            spec[2 + off] = batch_axes
        if name in ("k", "v", "xk", "xv") and l.ndim >= 6 + off:
            spec[4 + off] = "tensor"   # [S, (V,) Lps, B, len, heads, hd]
        return P(*spec)
    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def init_pipeline_cache(cfg: ArchConfig, plan: ST.StagePlan, batch: int,
                        max_len: int, *, dtype=jnp.float32, enc_len: int = 0):
    """Global cache [S, Lps, B, ...] (call under jit with sharding, or use
    eval_shape for the dry run).

    When n_kv_heads doesn't divide the tensor axis, the cache carries
    ``tensor`` head slots (one per device) — the inherent duplication of
    serving few-KV-head models under tensor parallelism."""
    tp = plan.tensor
    nkv = cfg.n_kv_heads
    if cfg.attn_kind == "gqa" and nkv % tp != 0:
        nh_l = max(1, cfg.n_heads // tp)
        g = cfg.n_heads // nkv
        nkv = tp * max(1, nh_l // g)
    pad_cfg = dataclasses.replace(cfg, n_layers=plan.n_layers_padded,
                                  n_kv_heads=nkv)
    c = M.init_cache(pad_cfg, batch, max_len, tp=1, dtype=dtype,
                     enc_len=enc_len)
    return jax.tree.map(lambda a: ST._stack_chunks(a, plan), c)


def _is_kv_len(path) -> bool:
    """True only for the ``kv`` subtree's ``len`` offset leaves — scoped so
    an unrelated cache field that happens to be named ``len`` (e.g. in a
    future ssm/audio extension) is never silently bumped."""
    keys = [getattr(p, "key", None) for p in path]
    return bool(keys) and keys[-1] == "len" and "kv" in keys[:-1]


def _restore_len(c_new, c_old):
    """Copy kv 'len' offsets back from c_old into c_new."""
    def pick(path, new, old):
        return old if _is_kv_len(path) else new
    return jax.tree_util.tree_map_with_path(pick, c_new, c_old)


def _advance_len(cache, adv):
    """Advance the kv 'len' offsets by ``adv`` — a scalar (uniform step) or
    a per-slot [B] vector (mixed prefill/decode: each request advances by
    its own valid-token count), broadcast over the trailing slot axis of
    the [Lc, B] / [V, Lc, B] leaves."""
    def bump(path, leaf):
        return leaf + adv if _is_kv_len(path) else leaf
    return jax.tree_util.tree_map_with_path(bump, cache)


def make_serve_step(cfg: ArchConfig, mesh: Mesh, plan: ST.StagePlan,
                    pcfg: PipelineConfig, *, batch_sharded: bool = True,
                    param_dtype=jnp.float32, cache_dtype=jnp.float32,
                    max_len: int = 0, global_batch: int = 0, q_len: int = 1,
                    enc_len: int = 0):
    """Build the jitted pipelined decode/prefill step:
    ``serve_step(params, cache, batch) -> (last_logits, cache)``.

    ``q_len=1`` is one-token decode; ``q_len=seq`` is prefill (KV/SSM cache
    populated, logits returned for the last position).  Micro-batches split
    the batch dimension; the per-stage cache is [Lps, B_loc, ...] and each
    tick dynamic-slices its micro-batch rows.  Cache ``len`` offsets are
    per-slot [B] vectors, frozen during the tick scan (each row is
    processed exactly once per step) and advanced once at the end.

    Continuous batching: the batch may carry ``n_valid`` [B] int32 — each
    slot then holds the first ``n_valid`` columns of its row as real
    tokens (``0`` = idle slot, ``1`` = decode, up to ``q_len`` = chunked
    prefill) and advances its cache offset by exactly that count.  Rows
    start at their own per-slot offsets, the returned ``[B, 1, vocab]``
    logits are gathered at each slot's last valid column, and garbage
    written by padding columns is causally masked and later overwritten,
    so mixed prefill chunks and decode ticks share one compiled step.
    Attention families only (ssm/hybrid/audio recurrent state has no
    per-token offsets to mask padding with).

    Interleaved (``plan.virtual`` = V > 1) plans replay the same compiled
    schedule table as training (cache leaves are [V, Lc, B, ...]; each
    tick chunk-indexes them).  For prefill the V-times-smaller flush
    bubble pays directly; one-token decode rides the same table — each
    extra ring lap adds S hops to the token's critical path, so it is a
    throughput-over-latency trade the serving scheduler opts into (e.g.
    to keep one parameter layout for both phases).
    """
    V = plan.virtual
    shape_params = jax.eval_shape(
        lambda k: ST.init_stacked_params(cfg, k, plan, param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    mesh_axes = tuple(mesh.axis_names)
    batch_axes = _batch_axes(mesh, pcfg)
    stage_ax = _stage_axes(mesh, pcfg)
    S = _n_stages(mesh, pcfg)
    assert plan.n_stages == S, \
        f"stage plan ({plan.n_stages}) != mesh pipeline depth ({S})"
    specs = ST.param_specs(cfg, shape_params, stage_axis=stage_ax,
                           fsdp_axis="data" if cfg.fsdp else None,
                           tensor_size=mesh.shape["tensor"], virtual=V)
    M_ = pcfg.n_microbatches
    sched = SP.resolve_ring_schedule(pcfg.schedule, V)
    ml = (pcfg.mem_limit or None) if sched == "zb-auto" else None
    lowering = SP.lower_to_ring(SP.build_schedule(sched, M_, S, V,
                                                  mem_limit=ml))
    fsdp_dims = ST.fsdp_scan_dims(specs, virtual=V) if cfg.fsdp else {}
    ep_dp_axis = "data" if (cfg.moe and cfg.moe.ep_data) else None
    ep_n_dp = mesh.shape["data"] if ep_dp_axis else 1

    cache_shapes = jax.eval_shape(
        functools.partial(init_pipeline_cache, cfg, plan, global_batch,
                          max_len, dtype=cache_dtype, enc_len=enc_len))
    cspecs = cache_specs(cfg, cache_shapes, batch_axes,
                         b_sharded=batch_sharded, stage_axis=stage_ax,
                         virtual=V)
    b_ax = batch_axes if batch_sharded else None

    def batch_spec_for(keys):
        sp = {}
        for kk in keys:
            if kk == "tokens":
                sp[kk] = P(b_ax, None)
            elif kk == "n_valid":
                sp[kk] = P(b_ax)
            elif kk == "pos3":
                sp[kk] = P(None, b_ax, None)
            else:
                raise ValueError(f"unknown serve batch key {kk!r}")
        return sp

    tab = _ring_tables(lowering)
    MV = M_ * V
    use_retbuf = lowering.needs_retbuf
    retbuf_sharded = use_retbuf and _shard_retbuf(cfg, S, stage_ax)

    def sharded_decode(params, cache, batch):
        stage_idx = lax.axis_index(stage_ax)
        tp_index = lax.axis_index("tensor")
        smeta = ST.stacked_meta(cfg, plan)
        smeta_local = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, stage_idx, 0, keepdims=False),
            smeta)
        lp_local = jax.tree.map(lambda a: a[0], params["layers"])
        cache_local = jax.tree.map(lambda a: a[0], cache)

        x_all = M.embed_tokens(cfg, params["embed"], batch["tokens"],
                               "tensor", tp_index)           # [B_loc,q,d]
        B_loc = x_all.shape[0]
        assert B_loc % M_ == 0
        mb = B_loc // M_
        # per-slot cache offsets -> per-row positions, sliced per micro-batch
        cur_len = jnp.asarray(M._cache_len(cache_local), jnp.int32)
        if cur_len.ndim == 0:
            cur_len = jnp.broadcast_to(cur_len, (B_loc,))
        pos_all = cur_len[:, None] + jnp.arange(q_len, dtype=jnp.int32)[None]
        if cfg.family == "audio":
            x_all = x_all + M.sinusoid_pos(pos_all, cfg.d_model, x_all.dtype)
        inj = x_all.reshape(M_, mb, q_len, -1)
        if cfg.family == "audio":
            # decode consumes the cross K/V cache; h_enc is vestigial
            inj = dict(h_dec=inj,
                       h_enc=jnp.zeros((M_, mb, 1, cfg.d_model), x_all.dtype))
        pos_mb = pos_all.reshape(M_, mb, q_len)
        pos3 = None
        if batch.get("pos3") is not None:
            pos3 = jnp.moveaxis(batch["pos3"].reshape(3, M_, mb, q_len), 1, 0)

        def tick(carry, t):
            if use_retbuf:
                x_cur, cache_l, outbuf, retbuf = carry
            else:
                x_cur, cache_l, outbuf = carry
                retbuf = None
            retbuf, x_in = _ring_ingest(tab, MV, S, stage_idx, t,
                                        inj, x_cur, retbuf,
                                        stage_ax=stage_ax,
                                        sharded=retbuf_sharded)
            # element (micro-batch, chunk) this stage works on at tick t
            e_idx = t - stage_idx
            valid = (e_idx >= 0) & (e_idx < MV)
            ecl = jnp.clip(e_idx, 0, MV - 1)
            mc = _at(tab["m"], ecl)
            if V > 1:
                chunk = _at(tab["v"], ecl)
                lp_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    lp_local)
                sm_t = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    smeta_local)
                cache_chunk = jax.tree.map(
                    lambda a: lax.dynamic_index_in_dim(a, chunk, 0,
                                                       keepdims=False),
                    cache_l)
            else:
                lp_t, sm_t, cache_chunk = lp_local, smeta_local, cache_l
            # slice this micro-batch's cache rows ([Lc, B_loc, ...] leaves;
            # 'len' counters are [Lc] and pass through whole)
            c_mb = jax.tree.map(
                lambda a: lax.dynamic_slice_in_dim(a, mc * mb, mb, 1)
                if a.ndim >= 2 else a, cache_chunk)
            p3 = None if pos3 is None else pos3[mc]
            pos_t = pos_mb[mc]

            def _run(args):
                x_in, c_mb = args
                y, _, c_new = apply_stage(
                    cfg, lp_t, sm_t, x_in, pos=pos_t, pos3=p3,
                    cache=c_mb, tp_axis="tensor", tp_index=tp_index,
                    dp_axis=ep_dp_axis, n_dp=ep_n_dp,
                    fsdp_axis="data" if cfg.fsdp else None,
                    fsdp_dims=fsdp_dims, remat="none",
                    unroll=pcfg.inner_unroll)
                return y, c_new

            if pcfg.gate_ticks:
                # validity is uniform across (data, tensor) for a fixed
                # (stage, tick), so collectives inside the branch are safe
                y, c_new = lax.cond(valid, _run, lambda a: a, (x_in, c_mb))
            else:
                y, c_new = _run((x_in, c_mb))
            # write back only when this tick was valid for this stage;
            # freeze 'len' counters (all micro-batches share the offset)
            c_new = jax.tree.map(
                lambda new, old: jnp.where(valid, new, old), c_new, c_mb)
            c_new = _restore_len(c_new, c_mb)
            cache_chunk = jax.tree.map(
                lambda full, blk: lax.dynamic_update_slice_in_dim(
                    full, blk.astype(full.dtype), mc * mb, 1)
                if full.ndim >= 2 else blk, cache_chunk, c_new)
            if V > 1:
                cache_l = jax.tree.map(
                    lambda full, blk: lax.dynamic_update_index_in_dim(
                        full, blk.astype(full.dtype), chunk, 0),
                    cache_l, cache_chunk)
            else:
                cache_l = cache_chunk
            # last stage emits the final (chunk V-1) last-position hidden
            out_e = t - (S - 1)
            oecl = jnp.clip(out_e, 0, MV - 1)
            oc = _at(tab["m"], oecl)
            do_collect = ((out_e >= 0) & _at(tab["collect"], oecl)
                          & (stage_idx == S - 1))
            curo = lax.dynamic_index_in_dim(outbuf, oc, 0, keepdims=False)
            wr = jnp.where(do_collect, _hidden_of(y), curo)
            outbuf = lax.dynamic_update_index_in_dim(outbuf, wr, oc, 0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = jax.tree.map(lambda a: lax.ppermute(a, stage_ax, perm), y)
            if use_retbuf:
                return (x_next, cache_l, outbuf, retbuf), None
            return (x_next, cache_l, outbuf), None

        x0 = jax.tree.map(lambda q: jnp.zeros_like(q[0]), inj)
        outbuf0 = jnp.zeros((M_, mb, q_len, cfg.d_model), x_all.dtype)
        carry0 = (x0, cache_local, outbuf0)
        if use_retbuf:
            carry0 = carry0 + (_retbuf_init(inj, S, retbuf_sharded),)
        carry_out, _ = lax.scan(
            tick, carry0, jnp.arange(lowering.n_ticks),
            unroll=pcfg.tick_scan_unroll)
        cache_local, outbuf = carry_out[1], carry_out[2]
        nv = batch.get("n_valid")
        adv = q_len if nv is None else nv.astype(jnp.int32)
        cache_local = _advance_len(cache_local, adv)

        # gather each slot's last *valid* column (uniform steps: column -1)
        hidden = outbuf.reshape(B_loc, q_len, -1)
        if nv is None:
            hidden = hidden[:, -1:]
        else:
            col = jnp.clip(nv.astype(jnp.int32), 1, q_len) - 1
            hidden = jnp.take_along_axis(hidden, col[:, None, None], axis=1)
        h = LYR.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
        table = params.get("head", params["embed"])
        logits = (h @ table.T).astype(jnp.float32)
        # broadcast real logits from the last stage to every stage
        on_last = (stage_idx == S - 1).astype(logits.dtype)
        logits = lax.psum(logits * on_last, stage_ax)
        new_cache = jax.tree.map(lambda a: a[None], cache_local)
        return logits, new_cache

    out_specs = (P(b_ax, None, "tensor"), cspecs)
    _built: dict = {}

    def fn(params, cache, batch):
        keys = tuple(sorted(batch))
        if "n_valid" in keys and cfg.family in ("ssm", "hybrid", "audio"):
            raise ValueError(
                f"continuous batching (n_valid) needs per-token cache "
                f"offsets to mask padding columns; the {cfg.family} "
                f"family carries recurrent ssm/conv (or cross-attention) "
                f"state that padding tokens would pollute — serve it "
                f"with uniform steps instead")
        if keys not in _built:
            _built[keys] = shard_map(
                sharded_decode, mesh=mesh,
                in_specs=(specs, cspecs, batch_spec_for(keys)),
                out_specs=out_specs, check_rep=False)
        return _built[keys](params, cache, batch)

    return jax.jit(fn, donate_argnums=(1,)), specs, cspecs, cache_shapes
