"""Stage partitioning: ArchConfig -> stacked per-stage parameters + specs.

The BaPipe partitioner decides *which contiguous layers* each stage owns;
the SPMD runtime requires homogeneous stages, so layers are stacked to
``[S, Lps, ...]`` (Lps = ceil(L/S)) with an ``active`` mask for the padded
slots (inactive slots pass activations through unchanged and contribute
zero gradient).  Padding waste is ≤ one layer per stage and is reported by
the roofline tooling (MODEL_FLOPS / HLO_FLOPs).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import model as M

# parameter-name classes for sharding rules -------------------------------
_TP_LAST = {"wq", "wk", "wv", "wq_b", "wkv_b", "w1", "w3"}   # output-dim sharded
_TP_PENULT = {"wo", "w2"}                                    # input-dim sharded
_TP_EXPERT = {"we1", "we2", "we3"}                           # expert-dim sharded
_FSDP_OK = _TP_LAST | _TP_PENULT | _TP_EXPERT | {"wq_a", "wkv_a", "in_proj",
                                                 "router"}


@dataclasses.dataclass(frozen=True)
class StagePlan:
    n_stages: int
    tensor: int
    layers_per_stage: int        # layers per CHUNK (a device owns `virtual`)
    n_layers_padded: int
    virtual: int = 1             # 1F1B-I interleave depth V (chunks/device)

    @property
    def pad(self) -> int:
        return self.n_layers_padded - 0


def plan_stages(cfg: ArchConfig, n_stages: Optional[int] = None,
                tensor: Optional[int] = None,
                virtual: Optional[int] = None) -> StagePlan:
    S = n_stages or cfg.stages
    tp = tensor or cfg.tensor
    V = virtual or cfg.virtual
    lps = math.ceil(cfg.n_layers / (S * V))
    return StagePlan(n_stages=S, tensor=tp, layers_per_stage=lps,
                     n_layers_padded=S * V * lps, virtual=V)


def _stack_chunks(a: jax.Array, plan: StagePlan) -> jax.Array:
    """[Lp, ...] -> [S, Lps, ...] (V == 1) or [S, V, Lc, ...] (V > 1),
    where element [n, v] is virtual stage v*S + n (Megatron assignment:
    a micro-batch's pass v visits devices 0..S-1 applying chunks
    v*S .. v*S + S - 1, so the global layer order is preserved)."""
    S, V, Lc = plan.n_stages, plan.virtual, plan.layers_per_stage
    if V == 1:
        return a.reshape((S, Lc) + a.shape[1:])
    return a.reshape((V, S, Lc) + a.shape[1:]).swapaxes(0, 1)


def unstack_chunks(a, plan: StagePlan):
    """Inverse of ``_stack_chunks``: recover the global [L, ...] layer order
    (used by checkpoints/reference comparisons)."""
    if plan.virtual == 1:
        return a.reshape((-1,) + a.shape[2:])
    return a.swapaxes(0, 1).reshape((-1,) + a.shape[3:])


def restack_layers(a, plan_from: StagePlan, plan_to: StagePlan,
                   n_layers: int):
    """Re-fold a layer-stacked leaf from one plan's chunk layout to
    another's (e.g. the interleaved-prefill [S, V, Lc, ...] cache into the
    contiguous [S, Lps, ...] decode layout): unstack to the global layer
    order, trim to the real layers, re-pad by repeating the last real
    layer (padded slots are inactive), restack for the target plan."""
    u = unstack_chunks(a, plan_from)[:n_layers]
    pad = plan_to.n_layers_padded - n_layers
    if pad:
        u = jnp.concatenate([u, jnp.repeat(u[-1:], pad, 0)], 0)
    return _stack_chunks(u, plan_to)


def restack_params(params: dict, plan_from: StagePlan, plan_to: StagePlan,
                   n_layers: int) -> dict:
    """Re-stack the ``layers`` subtree of a stacked parameter pytree from
    one stage plan to another (embed/head/final_norm pass through)."""
    out = dict(params)
    out["layers"] = jax.tree.map(
        lambda a: restack_layers(a, plan_from, plan_to, n_layers),
        params["layers"])
    return out


def init_stacked_params(cfg: ArchConfig, key: jax.Array, plan: StagePlan,
                        dtype=jnp.float32) -> dict:
    """Global (unsharded-shape) parameters with layers stacked [S, Lps, ...]
    (or [S, V, Lc, ...] for an interleaved plan).

    Vocab is padded so the embedding shards evenly over the tensor axis.
    """
    pad_cfg = dataclasses.replace(cfg, vocab=cfg.padded_vocab(plan.tensor))
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    Lp = plan.n_layers_padded
    layer_keys = jax.random.split(k_layers, Lp)
    stacked = jax.vmap(lambda k: M.init_block(cfg, k, 1, dtype))(layer_keys)
    stacked = jax.tree.map(lambda a: _stack_chunks(a, plan), stacked)
    p = dict(
        embed=jax.random.normal(k_emb, (pad_cfg.vocab, cfg.d_model), dtype)
        / math.sqrt(cfg.d_model),
        layers=stacked,
        final_norm=jnp.zeros((cfg.d_model,), dtype),
    )
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(k_out, (pad_cfg.vocab, cfg.d_model),
                                      dtype) / math.sqrt(cfg.d_model)
    return p


def stacked_meta(cfg: ArchConfig, plan: StagePlan) -> dict:
    """Per-layer metadata arrays reshaped to [S, Lps] — or [S, V, Lc] for an
    interleaved plan — plus the ``active`` mask for padded slots."""
    meta = M.layer_meta(cfg)
    Lp = plan.n_layers_padded
    pad = Lp - cfg.n_layers

    def expand(a):
        if pad:
            a = jnp.concatenate([a, jnp.repeat(a[-1:], pad, 0)], 0)
        return _stack_chunks(a, plan)

    out = {k: expand(v) for k, v in meta.items()}
    active = jnp.arange(Lp) < cfg.n_layers
    out["active"] = _stack_chunks(active, plan)
    return out


# ---------------------------------------------------------------------------
# PartitionSpecs
# ---------------------------------------------------------------------------

def param_specs(cfg: ArchConfig, params: dict, *, stage_axis="stage",
                tensor_axis="tensor", fsdp_axis=None,
                tensor_size: Optional[int] = None,
                virtual: int = 1) -> dict:
    """PartitionSpec pytree matching ``init_stacked_params`` output.

    If ``n_kv_heads`` doesn't divide the tensor axis, K/V projections are
    replicated (each device slices the kv head it needs at apply time).
    ``virtual`` > 1 shifts positional (expert) dims right by the extra
    leading chunk axis [S, V, Lc, ...]."""
    tp = tensor_size or cfg.tensor
    kv_replicated = (cfg.attn_kind == "gqa" and cfg.n_kv_heads % tp != 0)
    expert_dim = 2 if virtual == 1 else 3

    def leaf_spec(path, leaf):
        keys = [getattr(pp, "key", getattr(pp, "name", None)) for pp in path]
        name = keys[-1]
        if keys[0] in ("embed", "head"):
            return P(tensor_axis, None)
        if keys[0] == "final_norm":
            return P()
        # layers: leading [S, Lps] (or [S, V, Lc]); stage_axis may be a
        # tuple (pod, stage)
        nd = leaf.ndim
        spec = [stage_axis, None] + [None] * (nd - 2)
        if name in ("wk", "wv") and kv_replicated:
            return P(*spec)
        if name in _TP_EXPERT:
            if cfg.moe is not None and cfg.moe.ep_data:
                spec[expert_dim] = ("data", tensor_axis)  # expert parallel
            else:
                spec[expert_dim] = tensor_axis
                if fsdp_axis and cfg.fsdp:
                    spec[nd - 1] = fsdp_axis
        elif name in _TP_LAST:
            spec[nd - 1] = tensor_axis
            if fsdp_axis and cfg.fsdp:
                spec[nd - 2] = fsdp_axis
        elif name in _TP_PENULT:
            spec[nd - 2] = tensor_axis
            if fsdp_axis and cfg.fsdp:
                spec[nd - 1] = fsdp_axis
        elif fsdp_axis and cfg.fsdp and name in _FSDP_OK and nd >= 3:
            spec[nd - 1] = fsdp_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def fsdp_scan_dims(specs: dict, virtual: int = 1) -> dict:
    """Map layer-leaf name -> all_gather dim *after* the leading stacking
    dims are stripped: shard_map + the layer scan remove [S, Lps] for a
    contiguous plan, and [S, V, Lc] (stage, chunk select, layer scan) for
    an interleaved one — so the offset is 2 or 3 leading axes."""
    lead = 2 if virtual == 1 else 3
    out: dict = {}

    def visit(path, spec):
        keys = [getattr(pp, "key", None) for pp in path]
        name = keys[-1]
        for i, s in enumerate(spec):
            if s == "data":
                out[name] = i - lead
    jax.tree_util.tree_map_with_path(visit, specs["layers"])
    return out


def grad_sync_axes(spec: P, mesh_axes: tuple[str, ...]) -> tuple[str, ...]:
    """Axes a leaf is replicated over (its gradient must be psum'd there)."""
    used: set[str] = set()
    for s in spec:
        if s is None:
            continue
        if isinstance(s, tuple):
            used.update(s)
        else:
            used.add(s)
    return tuple(a for a in mesh_axes if a not in used)
