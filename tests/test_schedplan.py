"""Schedule-plan IR: the one compiled op table must (a) replay through the
discrete-event simulator to exactly the pre-IR makespans/closed forms,
(b) predict peak resident features by symbolic replay consistently with
both the O(1) algebraic rows and the timed simulator, and (c) lower onto
the ring runtime with the documented feasibility rules."""
import random

import pytest

from repro.core import schedplan as SP
from repro.core import schedules as S
from repro.core.simulator import simulate

RNG = random.Random(20260730)

GRID = []
for _ in range(40):
    N = RNG.randint(1, 6)
    GRID.append((N * RNG.randint(1, 5), N, RNG.choice([1, 2, 3, 4]),
                 round(RNG.uniform(0.1, 5.0), 3),
                 round(RNG.uniform(0.1, 5.0), 3)))


# ---------------------------------------------------------------------------
# (a) replaying the table reproduces PR 1's makespans.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,V,F,B", GRID)
def test_replay_reproduces_closed_form_makespans(M, N, V, F, B):
    """gpipe / 1f1b / 1f1b-interleaved replayed through the simulator give
    the pre-IR closed-form makespans exactly (free comm)."""
    assert simulate("gpipe", M, N, F, B, 0.0).makespan == \
        pytest.approx((M + N - 1) * (F + B), rel=1e-9)
    assert simulate("1f1b", M, N, F, B, 0.0).makespan == \
        pytest.approx(S.eval_1f1b_as(M, N, F, B, 0.0, 1.0, 1.0)
                      .minibatch_time, rel=1e-9)
    assert simulate("1f1b-interleaved", M, N, F, B, 0.0, V=V).makespan == \
        pytest.approx(S.eval_1f1b_interleaved(M, N, F, B, 0.0, 1.0, 1.0,
                                              V=V).minibatch_time, rel=1e-9)


@pytest.mark.parametrize("M,N,V,F,B", GRID)
def test_memlean_same_makespan_as_streaming(M, N, V, F, B):
    """The memory-lean order must not slow the pipeline down: identical
    makespan and bubble to streaming 1F1B-I (M % N == 0 grid)."""
    ml = simulate("1f1b-interleaved-memlean", M, N, F, B, 0.0, V=V)
    ev = S.eval_1f1b_interleaved_memlean(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    assert ml.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)
    assert ml.makespan == pytest.approx(
        simulate("1f1b-interleaved", M, N, F, B, 0.0, V=V).makespan,
        rel=1e-9)


# ---------------------------------------------------------------------------
# (b) features rows: symbolic replay == algebraic rows == timed simulator.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,V,F,B", GRID)
def test_symbolic_replay_matches_algebraic_counts(M, N, V, F, B):
    for name, fm in (("gpipe", 1), ("1F1B-AS", 1), ("FBP-AS", 2),
                     ("1F1B-I", V), ("1F1B-I-ML", V)):
        v = V if name in ("1F1B-I", "1F1B-I-ML") else 1
        plan = SP.build_schedule(name, M, N, v)
        replay = plan.peak_live()
        alg = SP.live_activation_counts(name, M, N, v,
                                        feat_mult=2 if name == "FBP-AS"
                                        else 1)
        for r, a in zip(replay, alg):
            assert abs(r - a) <= 1, (name, M, N, v, replay, alg)


@pytest.mark.parametrize("M,N,V,F,B", GRID)
def test_memlean_simulated_peak_live_matches_closed_form(M, N, V, F, B):
    """Acceptance: memlean's simulated peak-live equals its new closed
    form min(M*V, 2(N-i) + (V-1)N + 1) within the one-op greedy slack."""
    sim = simulate("1F1B-I-ML", M, N, F, B, 0.0, V=V)
    ev = S.eval_1f1b_interleaved_memlean(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    for i in range(N):
        want = min(M * V, 2 * (N - (i + 1)) + (V - 1) * N + 1)
        assert ev.features_memory[i] == pytest.approx(max(1, want))
        assert abs(sim.peak_live[i] - want) <= 1, \
            (i, sim.peak_live, ev.features_memory)


@pytest.mark.parametrize("M,N,V,F,B", GRID)
def test_memlean_features_below_streaming(M, N, V, F, B):
    """Acceptance: the memlean features term is < the streaming
    (V-1)M + N - i + 1 row whenever interleaving is real (V > 1) and
    there are strictly more micro-batches than stages."""
    if V == 1 or M <= N:
        pytest.skip("memory win needs V > 1 and M > N")
    ml = S.eval_1f1b_interleaved_memlean(M, N, 1.0, 1.0, 0.0, 1.0, 1.0, V=V)
    st = S.eval_1f1b_interleaved(M, N, 1.0, 1.0, 0.0, 1.0, 1.0, V=V)
    # stage 1 (the peak) must strictly improve; no stage may get worse
    assert ml.features_memory[0] < st.features_memory[0]
    assert all(m <= s for m, s in zip(ml.features_memory,
                                      st.features_memory))


# ---------------------------------------------------------------------------
# (c) builders, aliases, validation and ring lowering.
# ---------------------------------------------------------------------------

def test_canonical_names_and_aliases():
    assert SP.canonical_name("1F1B-AS") == "1f1b"
    assert SP.canonical_name("1F1B-SO") == "1f1b"
    assert SP.canonical_name("1F1B-I") == "1f1b-interleaved"
    assert SP.canonical_name("1F1B-I-ML") == "1f1b-interleaved-memlean"
    with pytest.raises(ValueError):
        SP.canonical_name("bogus")
    # legacy and canonical names build identical tables
    a = SP.build_schedule("1F1B-I", 4, 2, 2)
    b = SP.build_schedule("1f1b-interleaved", 4, 2, 2)
    assert a.device_ops == b.device_ops


def test_plan_validate_counts_every_op_once():
    plan = SP.build_schedule("1f1b-interleaved-memlean", 4, 2, 2)
    for n, ops in enumerate(plan.device_ops):
        assert len(ops) == 2 * 4 * 2
        fs = {(o.m, o.v) for o in ops if o.kind == "F"}
        bs = {(o.m, o.v) for o in ops if o.kind == "B"}
        assert fs == bs == {(m, v) for m in range(4) for v in range(2)}


def test_op_edges():
    plan = SP.build_schedule("1f1b-interleaved", 4, 2, 2)
    ops0 = plan.device_ops[0]
    f00 = next(o for o in ops0 if o.kind == "F" and o.m == 0 and o.v == 0)
    assert f00.vstage == 0 and f00.send_to == 1 and f00.recv_from is None
    f01 = next(o for o in ops0 if o.kind == "F" and o.m == 0 and o.v == 1)
    assert f01.vstage == 2 and f01.send_to == 3 and f01.recv_from == 1
    b01 = next(o for o in ops0 if o.kind == "B" and o.m == 0 and o.v == 1)
    assert b01.send_to == 1 and b01.recv_from == 3
    last = next(o for o in plan.device_ops[1]
                if o.kind == "F" and o.m == 0 and o.v == 1)
    assert last.vstage == 3 and last.send_to is None


def test_builders_reject_infeasible_shapes():
    with pytest.raises(ValueError, match="M >= N"):
        SP.build_1f1b_interleaved(2, 4, 2)
    with pytest.raises(ValueError, match="M % N == 0"):
        SP.build_1f1b_interleaved_memlean(6, 4, 2)
    with pytest.raises(ValueError):
        SP.build_schedule("1F1B-AS", 4, 2, V=2)


def test_ring_lowering_memlean_needs_no_return_buffer():
    """The memlean order consumes every ring return the tick it arrives
    (the gap between chunk passes of a micro-batch is exactly N), so the
    [M, ...] park buffer disappears from the runtime carry."""
    for (M, N, V) in ((4, 2, 2), (8, 4, 2), (6, 2, 3), (4, 4, 4)):
        lo = SP.lower_to_ring(
            SP.build_schedule("1f1b-interleaved-memlean", M, N, V))
        assert not lo.needs_retbuf
        assert sum(lo.direct) == M * (V - 1)
        assert sum(lo.fresh) == M
        assert sum(lo.collect) == M
        assert lo.n_ticks == M * V + N - 1


def test_ring_lowering_streaming_parks_early_passes():
    lo = SP.lower_to_ring(SP.build_schedule("1f1b-interleaved", 4, 2, 2))
    assert lo.needs_retbuf
    assert sum(lo.park) == 4          # every pass-0 return waits M - N ticks
    # at M == N the stream is tight: direct consumption, no buffer
    lo2 = SP.lower_to_ring(SP.build_schedule("1f1b-interleaved", 2, 2, 2))
    assert not lo2.needs_retbuf


def test_ring_lowering_v1_trivial():
    lo = SP.lower_to_ring(SP.build_schedule("1f1b", 5, 3))
    assert not lo.needs_retbuf
    assert all(lo.fresh) and all(lo.collect)
    assert lo.m_of_e == tuple(range(5))


def test_resolve_ring_schedule():
    assert SP.resolve_ring_schedule("auto", 1) == "1f1b"
    assert SP.resolve_ring_schedule("auto", 2) == "1f1b-interleaved"
    assert SP.resolve_ring_schedule("1F1B-I-ML", 2) == \
        "1f1b-interleaved-memlean"
    with pytest.raises(ValueError):
        SP.resolve_ring_schedule("1f1b", 2)     # contiguous order, V chunks


def test_memlean_closed_form_rejects_bad_M():
    with pytest.raises(ValueError, match="M % N"):
        S.eval_1f1b_interleaved_memlean(6, 4, 1.0, 1.0, 0.0, 1.0, 1.0, V=2)
