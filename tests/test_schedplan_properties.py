"""Schedule-conformance property suite: the invariants the runtime's
first-class-backward tick lowering depends on, for EVERY builder over
randomized (N, M, V) sweeps.

The mixed F/B(/W) tick scan (``pipeline/runtime.py``) executes whatever
``schedplan.lower_to_ticks`` emits; these properties are what make that
lowering sound:

* per-(m, v) causal order on every device: F before B (before W);
* every stage-boundary edge pairs up: an op that sends has exactly one
  consumer op with the matching receive edge on the neighbouring virtual
  stage;
* the synchronous tick assignment exists (no in-flight deadlock) and its
  tick count equals the discrete-event simulator's free-comm makespan at
  unit per-op durations — the two lowerings agree on the schedule;
* the symbolic ``peak_live()`` replay equals the O(1) algebraic
  features-memory rows, and the tick lowering's residual-stash size is
  exactly that row — the runtime's memory claim is structural.

Each test is parametrized over ``schedplan.BUILDER_NAMES`` (NOT a
hand-maintained list), so a new builder is conformance-checked the
moment it is registered.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

import pytest

from repro.core import schedplan as SP
from repro.core.simulator import simulate


def _shape(name, N, mmult, V):
    """Feasible (M, V) for a builder given the drawn knobs."""
    if name not in SP.INTERLEAVED:
        V = 1
    M = N * mmult            # M % N == 0 and M >= N: feasible for all
    return M, V


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=15)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_f_before_b_before_w(name, N, mmult, V):
    """On every device, each (m, v)'s F precedes its B, and (zero-bubble
    plans) its B precedes its W."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    for ops in plan.device_ops:
        pos = {(o.kind, o.m, o.v): i for i, o in enumerate(ops)}
        for (kind, m, vv), i in pos.items():
            if kind == "B":
                assert pos[("F", m, vv)] < i, (name, M, N, v)
            if kind == "W":
                assert pos[("B", m, vv)] < i, (name, M, N, v)


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=15)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_send_recv_edges_pair_up(name, N, mmult, V):
    """Every send edge has exactly one matching receive edge: F(m, vs)
    sending to vs+1 pairs with F(m, vs+1) receiving from vs (backwards
    mirrored); W ops never touch the ring."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    ops = [o for dev in plan.device_ops for o in dev]
    sends = {(o.kind, o.m, o.vstage, o.send_to)
             for o in ops if o.send_to is not None}
    recvs = {(o.kind, o.m, o.recv_from, o.vstage)
             for o in ops if o.recv_from is not None}
    assert sends == recvs, (name, M, N, v)
    assert all(o.send_to is None and o.recv_from is None
               for o in ops if o.kind == "W")
    # every interior edge is a single neighbour hop on the ring
    for kind, m, src, dst in sends:
        assert abs(dst - src) == 1


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=12)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_tick_lowering_no_deadlock_and_matches_simulator(name, N, mmult, V):
    """lower_to_ticks terminates (raises on any cyclic cross-device
    dependency) and its synchronous tick count equals the discrete-event
    free-comm makespan at unit per-op durations — i.e. one tick == one
    chunk-op, with the one-tick ppermute hop hidden exactly like the
    simulator's free transfers."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    lo = SP.lower_to_ticks(plan)
    ms = simulate(name, M, N, float(v),
                  float(v) * (2 if plan.has_w else 1), 0.0, V=v).makespan
    assert lo.n_ticks == pytest.approx(ms), (name, M, N, v)
    # one op per device per tick, each exactly once
    per_mv = 3 if plan.has_w else 2
    for n in range(N):
        kinds = [k for k in lo.kind[n] if k != SP.TICK_IDLE]
        assert len(kinds) == per_mv * M * v


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=12)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_instruction_stream_matches_simulator_event_order(name, N, mmult, V):
    """The instruction lowering's slot assignment IS the discrete-event
    schedule: at unit per-op durations under free comm, every op's slot
    equals its simulated start time — so the stream runtime's
    op-completion order is exactly the simulator's event order — and the
    ring gates (``fsend``/``bsend``) fire exactly at the slots where some
    device produces a value that travels."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    lo = SP.lower_to_instructions(plan)
    res = simulate(plan, M, N, float(v),
                   float(v) * (2 if plan.has_w else 1), 0.0, V=v,
                   comm="free")
    assert len(res.events) == len(lo.slot_of), (name, M, N, v)
    for (s, _e, kind, m, vs) in res.events:
        assert lo.slot_of[(kind, m, vs)] == pytest.approx(s), \
            (name, M, N, v, kind, m, vs)
    assert res.makespan == pytest.approx(lo.n_slots)
    # gates: a ring shifts at slot t iff some device SENDs on it there
    NS = N * v
    f_prod = {t for (k, _m, vs), t in lo.slot_of.items()
              if k == "F" and vs < NS - 1}
    b_prod = {t for (k, _m, vs), t in lo.slot_of.items()
              if k == "B" and vs > 0}
    assert {t for t, g in enumerate(lo.fsend) if g} == f_prod
    assert {t for t, g in enumerate(lo.bsend) if g} == b_prod
    # the point of the exercise: strictly fewer collectives than the
    # tick runtime's 2 * n_ticks (any schedule with an idle or W slot)
    assert lo.n_shifts <= 2 * lo.n_slots


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=15)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_peak_live_replay_matches_algebraic_rows(name, N, mmult, V):
    """``SchedPlan.peak_live()`` symbolic replay == the O(1)
    ``live_activation_counts`` rows for every builder (dapple and zb-h1
    hold 1F1B's N - n window; zb-h2 the deep-warm-up/banked-W row;
    unbounded zb-auto pays M for its bubble-free steady state)."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    replay = plan.peak_live()
    alg = SP.live_activation_counts(name, M, N, v)
    for r, a in zip(replay, alg):
        assert abs(r - a) <= 1, (name, M, N, v, replay, alg)


@pytest.mark.parametrize("name", SP.BUILDER_NAMES)
@settings(max_examples=12)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_residual_stash_is_the_features_row(name, N, mmult, V):
    """The tick lowering's statically allocated residual stash (``n_x``)
    equals the schedule's peak-live row — the runtime's features-memory
    footprint IS the closed form's, by register allocation."""
    M, v = _shape(name, N, mmult, V)
    plan = SP.build_schedule(name, M, N, v)
    lo = SP.lower_to_ticks(plan)
    assert lo.n_x == max(plan.peak_live()), (name, M, N, v)


@settings(max_examples=20)
@given(N=st.integers(2, 6), mmult=st.integers(1, 4))
def test_zb_h1_holds_the_1f1b_memory_window(N, mmult):
    """Acceptance (ZB-H1 is the '1F1B-equivalent memory' zero-bubble
    point): its residual window equals dapple/1f1b's N - n on every
    device, while the simulator makespan is strictly smaller."""
    M = N * mmult
    zb = SP.build_schedule("zb-h1", M, N, 1)
    da = SP.build_schedule("dapple", M, N, 1)
    assert zb.peak_live() == da.peak_live()
    ms_zb = simulate("zb-h1", M, N, 1.0, 1.0, 0.0).makespan
    ms_da = simulate("dapple", M, N, 1.0, 1.0, 0.0).makespan
    assert ms_zb < ms_da


@settings(max_examples=25)
@given(N=st.integers(1, 6), mmult=st.integers(1, 6))
def test_zb_auto_reproduces_zb_h1_under_the_1f1b_cap(N, mmult):
    """Acceptance: the automatic zero-bubble scheduler under the 1F1B
    memory cap (per-device window N - n) emits EXACTLY ZB-H1's op table
    — the hand-written schedule is a special case of the cap."""
    M = N * mmult
    cap = [max(1, min(M, N - n)) for n in range(N)]
    auto = SP.build_zb_auto(M, N, mem_limit=cap)
    h1 = SP.build_zb_h1(M, N)
    assert auto.device_ops == h1.device_ops, (M, N)


@settings(max_examples=25)
@given(N=st.integers(1, 6), mmult=st.integers(1, 6))
def test_zb_h2_is_zb_auto_under_the_h2_cap(N, mmult):
    """ZB-H2 is definitionally the automatic scheduler's table under
    :func:`schedplan.zb_h2_mem_caps` — pin the derivation, and that its
    peak-live row equals the cap exactly (the cap is attained)."""
    M = N * mmult
    h2 = SP.build_zb_h2(M, N)
    auto = SP.build_zb_auto(M, N, mem_limit=SP.zb_h2_mem_caps(M, N))
    assert h2.device_ops == auto.device_ops
    assert h2.peak_live() == SP.zb_h2_mem_caps(M, N)


@settings(max_examples=20)
@given(N=st.integers(1, 6), mmult=st.integers(2, 6))
def test_zb_h2_and_unbounded_auto_are_bubble_free_in_ticks(N, mmult):
    """Acceptance: for M >= 2N the zb-h2 table's synchronous tick count
    is exactly ``3M + N - 1`` — unit-cost M(F+B) work plus only the
    ``N - 1`` fill ramp; the entire 1F1B flush bubble is gone — and the
    unbounded zb-auto table matches it while gpipe/1f1b/zb-h1 sit
    strictly above (N > 1)."""
    M = N * mmult            # mmult >= 2 -> M >= 2N
    target = 3 * M + N - 1
    for name in ("zb-h2", "zb-auto"):
        lo = SP.lower_to_ticks(SP.build_schedule(name, M, N, 1))
        assert lo.n_ticks == target, (name, M, N, lo.n_ticks)
    if N > 1:
        h1 = SP.lower_to_ticks(SP.build_schedule("zb-h1", M, N, 1))
        assert h1.n_ticks > target


def test_dapple_table_equals_1f1b():
    """The documented 'dapple coincides with synchronous 1F1B' invariant
    is structural (the builder derives from build_1f1b) — pin it."""
    for (M, N) in ((4, 2), (8, 4), (6, 3), (5, 5)):
        da = SP.build_schedule("dapple", M, N, 1)
        fb = SP.build_schedule("1f1b", M, N, 1)
        assert da.device_ops == fb.device_ops


def test_dapple_table_is_early_backward():
    """DAPPLE's first backward on the last device comes directly after
    its first forward — M - 1 forwards earlier than gpipe's."""
    M, N = 8, 4
    da = SP.build_schedule("dapple", M, N, 1)
    gp = SP.build_schedule("gpipe", M, N, 1)
    first_b = lambda p, n: [o.kind for o in p.device_ops[n]].index("B")
    assert first_b(da, N - 1) == 1
    assert first_b(gp, N - 1) == M


def test_zb_h1_w_fills_the_drain():
    """The drain tail of every zb-h1 device alternates B, W (the W's fill
    what would otherwise be bubbles), ending on the last W."""
    plan = SP.build_schedule("zb-h1", 8, 4, 1)
    for n, ops in enumerate(plan.device_ops):
        tail = [o.kind for o in ops[-2 * (4 - n):]]
        assert tail == ["B", "W"] * (4 - n), (n, tail)


def test_zb_h2_has_double_warmup_and_banked_drain_ws():
    """ZB-H2's structure: device n warms up with ``2(N-n) - 1`` forwards
    (double 1F1B's depth) and the downstream devices end in a run of
    banked W ops that fills the drain."""
    M, N = 12, 4
    plan = SP.build_schedule("zb-h2", M, N, 1)
    for n, ops in enumerate(plan.device_ops):
        kinds = [o.kind for o in ops]
        assert kinds.index("B") == 2 * (N - n) - 1, (n, kinds)
    # the last device banks the deepest W backlog: a strictly longer
    # trailing all-W run than device 0's
    ws = [0] * N
    for n in range(N):
        k = [o.kind for o in plan.device_ops[n]]
        t = 0
        while k and k[-1] == "W":
            k.pop(); t += 1
        ws[n] = t
    assert ws[N - 1] > ws[0], ws


def test_build_schedule_mem_limit_only_for_zb_auto():
    """The mem_limit knob belongs to the automatic scheduler alone."""
    with pytest.raises(ValueError, match="mem_limit"):
        SP.build_schedule("zb-h1", 4, 2, 1, mem_limit=3)
    plan = SP.build_schedule("zb-auto", 4, 2, 1, mem_limit=2)
    assert max(plan.peak_live()) <= 2
