"""Schedule-conformance property suite: the invariants the runtime's
first-class-backward tick lowering depends on, for EVERY builder over
randomized (N, M, V) sweeps.

The mixed F/B(/W) tick scan (``pipeline/runtime.py``) executes whatever
``schedplan.lower_to_ticks`` emits; these properties are what make that
lowering sound:

* per-(m, v) causal order on every device: F before B (before W);
* every stage-boundary edge pairs up: an op that sends has exactly one
  consumer op with the matching receive edge on the neighbouring virtual
  stage;
* the synchronous tick assignment exists (no in-flight deadlock) and its
  tick count equals the discrete-event simulator's free-comm makespan at
  unit per-op durations — the two lowerings agree on the schedule;
* the symbolic ``peak_live()`` replay equals the O(1) algebraic
  features-memory rows, and the tick lowering's residual-stash size is
  exactly that row — the runtime's memory claim is structural.
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

import pytest

from repro.core import schedplan as SP
from repro.core.simulator import simulate

BUILDERS = SP.BUILDER_NAMES


def _draw_shape(name, N, mmult, V):
    """Feasible (M, V) for a builder given the drawn knobs."""
    if name not in SP.INTERLEAVED:
        V = 1
    M = N * mmult            # M % N == 0 and M >= N: feasible for all
    return M, V


def _plans(N, mmult, V):
    for name in BUILDERS:
        M, v = _draw_shape(name, N, mmult, V)
        yield name, M, v, SP.build_schedule(name, M, N, v)


@settings(max_examples=25)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_f_before_b_before_w(N, mmult, V):
    """On every device, each (m, v)'s F precedes its B, and (zero-bubble
    plans) its B precedes its W."""
    for name, M, v, plan in _plans(N, mmult, V):
        for ops in plan.device_ops:
            pos = {(o.kind, o.m, o.v): i for i, o in enumerate(ops)}
            for (kind, m, vv), i in pos.items():
                if kind == "B":
                    assert pos[("F", m, vv)] < i, (name, M, N, v)
                if kind == "W":
                    assert pos[("B", m, vv)] < i, (name, M, N, v)


@settings(max_examples=25)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_send_recv_edges_pair_up(N, mmult, V):
    """Every send edge has exactly one matching receive edge: F(m, vs)
    sending to vs+1 pairs with F(m, vs+1) receiving from vs (backwards
    mirrored); W ops never touch the ring."""
    for name, M, v, plan in _plans(N, mmult, V):
        ops = [o for dev in plan.device_ops for o in dev]
        sends = {(o.kind, o.m, o.vstage, o.send_to)
                 for o in ops if o.send_to is not None}
        recvs = {(o.kind, o.m, o.recv_from, o.vstage)
                 for o in ops if o.recv_from is not None}
        assert sends == recvs, (name, M, N, v)
        assert all(o.send_to is None and o.recv_from is None
                   for o in ops if o.kind == "W")
        # every interior edge is a single neighbour hop on the ring
        for kind, m, src, dst in sends:
            assert abs(dst - src) == 1


@settings(max_examples=20)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_tick_lowering_no_deadlock_and_matches_simulator(N, mmult, V):
    """lower_to_ticks terminates (raises on any cyclic cross-device
    dependency) and its synchronous tick count equals the discrete-event
    free-comm makespan at unit per-op durations — i.e. one tick == one
    chunk-op, with the one-tick ppermute hop hidden exactly like the
    simulator's free transfers."""
    for name, M, v, plan in _plans(N, mmult, V):
        lo = SP.lower_to_ticks(plan)
        ms = simulate(name, M, N, float(v),
                      float(v) * (2 if plan.has_w else 1), 0.0, V=v).makespan
        assert lo.n_ticks == pytest.approx(ms), (name, M, N, v)
        # one op per device per tick, each exactly once
        per_mv = 3 if plan.has_w else 2
        for n in range(N):
            kinds = [k for k in lo.kind[n] if k != SP.TICK_IDLE]
            assert len(kinds) == per_mv * M * v


@settings(max_examples=25)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_peak_live_replay_matches_algebraic_rows(N, mmult, V):
    """``SchedPlan.peak_live()`` symbolic replay == the O(1)
    ``live_activation_counts`` rows for every builder (dapple and zb-h1
    hold 1F1B's N - n window)."""
    for name, M, v, plan in _plans(N, mmult, V):
        replay = plan.peak_live()
        alg = SP.live_activation_counts(name, M, N, v)
        for r, a in zip(replay, alg):
            assert abs(r - a) <= 1, (name, M, N, v, replay, alg)


@settings(max_examples=20)
@given(N=st.integers(1, 6), mmult=st.integers(1, 4), V=st.integers(1, 4))
def test_residual_stash_is_the_features_row(N, mmult, V):
    """The tick lowering's statically allocated residual stash (``n_x``)
    equals the schedule's peak-live row — the runtime's features-memory
    footprint IS the closed form's, by register allocation."""
    for name, M, v, plan in _plans(N, mmult, V):
        lo = SP.lower_to_ticks(plan)
        assert lo.n_x == max(plan.peak_live()), (name, M, N, v)


@settings(max_examples=20)
@given(N=st.integers(2, 6), mmult=st.integers(1, 4))
def test_zb_h1_holds_the_1f1b_memory_window(N, mmult):
    """Acceptance (ZB-H1 is the '1F1B-equivalent memory' zero-bubble
    point): its residual window equals dapple/1f1b's N - n on every
    device, while the simulator makespan is strictly smaller."""
    M = N * mmult
    zb = SP.build_schedule("zb-h1", M, N, 1)
    da = SP.build_schedule("dapple", M, N, 1)
    assert zb.peak_live() == da.peak_live()
    ms_zb = simulate("zb-h1", M, N, 1.0, 1.0, 0.0).makespan
    ms_da = simulate("dapple", M, N, 1.0, 1.0, 0.0).makespan
    assert ms_zb < ms_da


def test_dapple_table_equals_1f1b():
    """The documented 'dapple coincides with synchronous 1F1B' invariant
    is structural (the builder derives from build_1f1b) — pin it."""
    for (M, N) in ((4, 2), (8, 4), (6, 3), (5, 5)):
        da = SP.build_schedule("dapple", M, N, 1)
        fb = SP.build_schedule("1f1b", M, N, 1)
        assert da.device_ops == fb.device_ops


def test_dapple_table_is_early_backward():
    """DAPPLE's first backward on the last device comes directly after
    its first forward — M - 1 forwards earlier than gpipe's."""
    M, N = 8, 4
    da = SP.build_schedule("dapple", M, N, 1)
    gp = SP.build_schedule("gpipe", M, N, 1)
    first_b = lambda p, n: [o.kind for o in p.device_ops[n]].index("B")
    assert first_b(da, N - 1) == 1
    assert first_b(gp, N - 1) == M


def test_zb_h1_w_fills_the_drain():
    """The drain tail of every zb-h1 device alternates B, W (the W's fill
    what would otherwise be bubbles), ending on the last W."""
    plan = SP.build_schedule("zb-h1", 8, 4, 1)
    for n, ops in enumerate(plan.device_ops):
        tail = [o.kind for o in ops[-2 * (4 - n):]]
        assert tail == ["B", "W"] * (4 - n), (n, tail)
