"""Heterogeneous per-device costs end to end (ISSUE 5): the StageCosts
vector interface from the profiler's measured B/W split through the
cost-shaped zb-auto builder, the vector-duration simulator, the
``eval_*_hetero`` closed forms and the explorer's scheduled-makespan
ranking.

Pinned invariants:

* uniform cost vectors reproduce today's tables (exact op-table
  equality) and closed forms (bit-exact delegation);
* the randomized heterogeneous ``(M, N, F_n, B_n, W_n, mem_limit)``
  differential sweep: the cost-shaped zb-auto eval == the simulator
  replay of its table, ``zb-auto(vector) <= zb-auto(max-scalar)``
  (structural, via the builder's scalar-collapse portfolio), and the
  peak-live row never exceeds the cap;
* the analytic heterogeneous bottleneck floors bracket every replay
  from below (exact at each form's design point);
* acceptance: on a skewed 4-device cluster the explorer's cost-shaped
  zb-auto plan strictly beats the best uniform-scalar plan, both
  replayed at the true per-device durations (simulator-pinned).
"""
import dataclasses
import random

import pytest

from repro.core import schedplan as SP
from repro.core import schedules as S
from repro.core.explorer import explore
from repro.core.hardware import DeviceSpec, heterogeneous_cluster
from repro.core.profiler import LayerProfile, NetworkProfile
from repro.core.simulator import simulate, simulate_costs

RNG = random.Random(20260731)


def _rand_costs(N, with_sr=False):
    return SP.StageCosts(
        F=[round(RNG.uniform(0.1, 5.0), 3) for _ in range(N)],
        B=[round(RNG.uniform(0.1, 5.0), 3) for _ in range(N)],
        W=[round(RNG.uniform(0.1, 5.0), 3) for _ in range(N)],
        SR=[round(RNG.uniform(0.0, 0.3), 3) for _ in range(N - 1)]
        if with_sr else ())


HGRID = []
for _ in range(60):
    N = RNG.randint(1, 6)
    HGRID.append((RNG.randint(N, 24), N, _rand_costs(N),
                  RNG.choice([0, N, N + 1, 2 * N, 2 * N + 3])))


# ---------------------------------------------------------------------------
# StageCosts basics.
# ---------------------------------------------------------------------------

def test_stagecosts_validation_and_views():
    c = SP.StageCosts(F=(1.0, 2.0), B=(1.0, 1.0), W=(3.0, 1.0),
                      SR=(0.25,))
    assert c.n == 2 and not c.uniform and not c.even_split
    assert c.B_full == (4.0, 2.0)
    assert c.w_frac == (0.75, 0.5)
    assert c.bottleneck() == (2.0, 4.0, 0.25)
    ms = c.max_scalar()
    assert ms.uniform and ms.F == (2.0, 2.0) and ms.W == (3.0, 3.0)
    u = SP.StageCosts.uniform_costs(3, 1.0, 2.0, w_frac=0.25)
    assert u.uniform and u.B == (1.5,) * 3 and u.W == (0.5,) * 3
    with pytest.raises(ValueError, match="positive"):
        SP.StageCosts(F=(1.0, 0.0), B=(1.0, 1.0), W=(1.0, 1.0))
    with pytest.raises(ValueError, match="hop"):
        SP.StageCosts(F=(1.0, 1.0), B=(1.0, 1.0), W=(1.0, 1.0),
                      SR=(0.1, 0.1))
    with pytest.raises(ValueError, match="disagree"):
        SP.StageCosts(F=(1.0, 1.0), B=(1.0,), W=(1.0, 1.0))


# ---------------------------------------------------------------------------
# Uniform vectors reproduce today's tables and closed forms exactly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,costs,mem_limit", HGRID[:20])
def test_uniform_vectors_reproduce_scalar_tables(M, N, costs, mem_limit):
    """build_zb_auto under a *uniform* vector (every device the scalar
    costs) emits EXACTLY the scalar interface's op table — including
    through the StageCosts form."""
    cap = mem_limit or None
    F, Bc, Wc = costs.F[0], costs.B[0], costs.W[0]
    scalar = SP.build_zb_auto(M, N, costs=(F, Bc, Wc), mem_limit=cap)
    vec = SP.build_zb_auto(M, N, costs=([F] * N, [Bc] * N, [Wc] * N),
                           mem_limit=cap)
    sc = SP.build_zb_auto(
        M, N, costs=SP.StageCosts(F=(F,) * N, B=(Bc,) * N, W=(Wc,) * N),
        mem_limit=cap)
    assert scalar.device_ops == vec.device_ops == sc.device_ops


def test_uniform_vectors_reduce_evals_bit_exactly():
    """Every eval_*_hetero under a uniform even-split vector returns the
    scalar closed form's exact numbers (delegation, not approximation)."""
    M, N, F, B, a, w = 12, 4, 1.3, 2.6, 4.0, 10.0
    costs = SP.StageCosts.uniform_costs(N, F, B)
    pairs = [
        (S.eval_1f1b_as_hetero(M, N, costs, a, w),
         S.eval_1f1b_as(M, N, F, B, 0.0, a, w)),
        (S.eval_fbp_as_hetero(M, N, costs, a, w),
         S.eval_fbp_as(M, N, F, B, 0.0, a, w)),
        (S.eval_dapple_hetero(M, N, costs, a, w),
         S.eval_dapple(M, N, F, B, 0.0, a, w)),
        (S.eval_zb_h1_hetero(M, N, costs, a, w),
         S.eval_zb_h1(M, N, F, B, 0.0, a, w)),
        (S.eval_zb_h2_hetero(M, N, costs, a, w),
         S.eval_zb_h2(M, N, F, B, 0.0, a, w)),
        (S.eval_zb_auto_hetero(M, N, costs, a, w, mem_limit=N),
         S.eval_zb_auto(M, N, F, B, 0.0, a, w, mem_limit=N)),
    ]
    for het, uni in pairs:
        assert het == uni, (het.name, het, uni)


# ---------------------------------------------------------------------------
# Randomized heterogeneous differential sweep (satellite acceptance).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,costs,mem_limit", HGRID)
def test_zb_auto_hetero_differential_sweep(M, N, costs, mem_limit):
    """Cost-shaped zb-auto: (a) the eval's reported makespan IS the
    simulator replay of the emitted table under the per-device
    durations; (b) ``zb-auto(vector) <= zb-auto(max-scalar)`` — both
    tables replayed at the TRUE vector costs (structural via the
    builder's scalar-collapse portfolio); (c) the emitted table's
    peak-live row never exceeds the cap."""
    cap = mem_limit or None
    vec = SP.build_zb_auto(M, N, costs=(list(costs.F), list(costs.B),
                                        list(costs.W)), mem_limit=cap)
    mk = (costs.max_scalar().F[0], costs.max_scalar().B[0],
          costs.max_scalar().W[0])
    sca = SP.build_zb_auto(M, N, costs=mk, mem_limit=cap)

    def replay(plan):
        return simulate(plan, M, N, list(costs.F), list(costs.B_full),
                        0.0, w_frac=list(costs.w_frac)).makespan

    t_vec, t_sca = replay(vec), replay(sca)
    assert t_vec <= t_sca + 1e-9, (t_vec, t_sca)
    ev = S.eval_zb_auto_hetero(M, N, costs, 1.0, 1.0, mem_limit=cap)
    assert ev.minibatch_time == pytest.approx(t_vec, rel=1e-12)
    assert list(ev.features_memory) == [float(p) for p in vec.peak_live()]
    caps = [max(1, min(M, mem_limit))] * N if mem_limit else [M] * N
    assert all(p <= c for p, c in zip(vec.peak_live(), caps))


@pytest.mark.parametrize("M,N,costs,mem_limit", HGRID)
def test_hetero_floors_bracket_the_replays(M, N, costs, mem_limit):
    """The analytic heterogeneous bottleneck floors bound every
    schedule's replay from below: full-backward drain for 1F1B/DAPPLE,
    input-gradient drain for ZB-H1, work-and-fill for ZB-H2 and the
    unbounded automatic scheduler."""
    for name, drain in (("1F1B-AS", "full"), ("DAPPLE", "full"),
                        ("ZB-H1", "input"), ("ZB-H2", "none")):
        ev = S.HETERO_SCHEDULES[name](M, N, costs, 1.0, 1.0)
        floor = S.hetero_makespan_floor(M, costs, drain=drain)
        assert floor <= ev.minibatch_time + 1e-9, (name, floor, ev)
    ev = S.eval_zb_auto_hetero(M, N, costs, 1.0, 1.0)
    floor = S.hetero_makespan_floor(M, costs, drain="none")
    assert floor <= ev.minibatch_time + 1e-9


def test_hetero_floor_exact_at_uniform_design_points():
    """Uniform vectors recover the closed forms from the generalised
    floor: full drain -> (M+N-1)(F+B); input drain at the even split ->
    M(F+B) + (N-1)(F+B/2); no drain -> M(F+B) + (N-1)F."""
    M, N, F, B = 9, 4, 1.1, 2.2
    u = SP.StageCosts.uniform_costs(N, F, B)
    assert S.hetero_makespan_floor(M, u, "full") == \
        pytest.approx((M + N - 1) * (F + B), rel=1e-12)
    assert S.hetero_makespan_floor(M, u, "input") == \
        pytest.approx(M * (F + B) + (N - 1) * (F + B / 2), rel=1e-12)
    assert S.hetero_makespan_floor(M, u, "none") == \
        pytest.approx(M * (F + B) + (N - 1) * F, rel=1e-12)
    with pytest.raises(ValueError, match="drain"):
        S.hetero_makespan_floor(M, u, "bogus")


# ---------------------------------------------------------------------------
# Vector-duration simulator: per-device w_frac, per-hop SR.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,N,costs,mem_limit", HGRID[:25])
def test_simulator_per_hop_sr_ordering(M, N, costs, mem_limit):
    """Per-hop SR vectors: free <= latency(per-hop) <= latency(max-hop)
    <= blocking(max-hop), and a zero vector equals free exactly."""
    sr = [round(RNG.uniform(0.0, 0.2), 3) for _ in range(N - 1)]
    args = (M, N, list(costs.F), list(costs.B_full))
    wf = list(costs.w_frac)
    free = simulate("zb-auto", *args, 0.0, w_frac=wf).makespan
    zero = simulate("zb-auto", *args, [0.0] * (N - 1), comm="latency",
                    w_frac=wf).makespan
    assert zero == pytest.approx(free, rel=1e-12)
    lat = simulate("zb-auto", *args, sr, comm="latency", w_frac=wf).makespan
    mx = max(sr, default=0.0)
    lat_mx = simulate("zb-auto", *args, mx, comm="latency",
                      w_frac=wf).makespan
    blk_mx = simulate("zb-auto", *args, mx, comm="blocking",
                      w_frac=wf).makespan
    assert free <= lat + 1e-9 <= lat_mx + 2e-9 <= blk_mx + 3e-9


def test_simulator_rejects_bad_vectors():
    with pytest.raises(ValueError, match="w_frac"):
        simulate("zb-h1", 4, 2, 1.0, 1.0, 0.0, w_frac=[0.5])
    with pytest.raises(ValueError, match="w_frac"):
        simulate("zb-h1", 4, 2, 1.0, 1.0, 0.0, w_frac=[0.5, 1.5])
    with pytest.raises(ValueError, match="hop"):
        simulate("1f1b", 4, 2, 1.0, 1.0, [0.1, 0.1])
    with pytest.raises(ValueError, match="SR"):
        simulate("1f1b", 4, 2, 1.0, 1.0, -0.1)


def test_simulate_costs_matches_builder_arrival_model():
    """simulate_costs replays a cost-shaped table under the same
    latency-arrival model the SR-aware builder scheduled with, so the
    two agree; it rejects mismatched N."""
    for _ in range(10):
        N = RNG.randint(2, 5)
        M = N * RNG.randint(2, 4)
        costs = _rand_costs(N, with_sr=True)
        plan = SP.build_zb_auto(M, N, costs=costs)
        t = simulate_costs(plan, M, N, costs).makespan
        t2 = SP._replay_makespan(plan, costs.F, costs.B, costs.W,
                                 costs.sr_hops)
        assert t == pytest.approx(t2, rel=1e-12)
    with pytest.raises(ValueError, match="devices"):
        simulate_costs("zb-h1", 4, 3, _rand_costs(2))


# ---------------------------------------------------------------------------
# Profiler -> partition: the measured split and per-hop SR flow through.
# ---------------------------------------------------------------------------

def test_partition_cost_vector_carries_split_and_per_hop_sr():
    """PartitionPlan.cost_vector(): per-device B/W from the layers'
    w_frac (not the even split), per-hop SR from each boundary's actual
    link bandwidth (satellite: no max() collapse)."""
    from repro.core.partition import dp_partition
    layers = tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9, w_frac=0.3) for i in range(8))
    prof = NetworkProfile("toy", layers, unit="sample")
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 100e9,
                      async_capable=True, efficiency=1.0)
    slow_link = dataclasses.replace(fast, name="slow_link",
                                    link_bandwidth=10e9)
    cl = heterogeneous_cluster([fast, slow_link, fast, fast])
    plan = dp_partition(prof, cl, mb=1, include_embed_head=False)
    costs = plan.cost_vector()
    assert costs.n == 4 and len(costs.SR) == 3
    # w_frac flows through: every device's W share is the profiled 0.3
    for b, w in zip(costs.B, costs.W):
        assert w / (b + w) == pytest.approx(0.3, rel=1e-9)
    # per-hop SR: hop 0 and hop 1 touch the slow 10 GB/s link (min of
    # endpoint transceivers), hop 2 runs fast-fast at 100 GB/s
    assert costs.SR[0] == pytest.approx(1e9 / 10e9)
    assert costs.SR[1] == pytest.approx(1e9 / 10e9)
    assert costs.SR[2] == pytest.approx(1e9 / 100e9)


def test_profiler_w_frac_analytic_and_measured():
    """LayerProfile.w_frac: attention layers sit below the 0.5
    pure-matmul point (QK^T/PV have no dL/dw); the measured mode returns
    a vjp-timed fraction in (0, 1) or falls back to analytic."""
    from repro.configs import get_config
    from repro.core.profiler import (bwd_split_time, bwd_time,
                                     measure_w_frac, profile_arch)
    from repro.core.hardware import TPU_V5E
    cfg = get_config("llama3.2-1b")
    prof = profile_arch(cfg, seq=4096)
    for l in prof.layers:
        assert 0.0 < l.w_frac < 0.5      # attention span work dilutes W
    b, w = bwd_split_time(prof.layers[0], TPU_V5E, 64)
    assert b + w == pytest.approx(bwd_time(prof.layers[0], TPU_V5E, 64))
    assert w / (b + w) == pytest.approx(prof.layers[0].w_frac)
    # measured mode: a real vjp timing (or None -> analytic fallback)
    wf = measure_w_frac(cfg, seq=32, iters=2)
    assert wf is None or 0.0 < wf < 1.0
    measured_cfg = dataclasses.replace(cfg, profile_w_frac="measured")
    mprof = profile_arch(measured_cfg, seq=64)
    for l in mprof.layers:
        assert 0.0 < l.w_frac < 1.0
    with pytest.raises(ValueError, match="w_frac"):
        LayerProfile("bad", 1.0, 1.0, 1.0, w_frac=1.5)


def test_measured_w_frac_per_layer_kind(monkeypatch):
    """Bugfix pin: a mixed attention+MoE trunk no longer smears ONE
    measured proxy timing over every layer — ``profile_arch`` measures
    once per distinct layer kind and each row inherits its own kind's
    split, falling back analytically per layer for kinds that fail to
    time."""
    from repro.configs import get_config
    from repro.core import profiler as P
    cfg = get_config("deepseek-v2-lite-16b").reduced(d_model=64)
    # 2 reduced layers, first_k_dense=1 -> layer 0 dense, layer 1 moe
    cfg = dataclasses.replace(
        cfg, profile_w_frac="measured",
        moe=dataclasses.replace(cfg.moe, first_k_dense=1))
    assert [P.layer_kind(cfg, i) for i in range(cfg.n_layers)] == \
        ["dense", "moe"]
    real_measure = P.measure_w_frac
    fakes = {"dense": 0.21, "moe": 0.47}
    calls = []
    monkeypatch.setattr(
        P, "measure_w_frac",
        lambda c, seq=128, iters=5, kind="dense":
        calls.append(kind) or fakes[kind])
    prof = P.profile_arch(cfg, seq=64)
    assert sorted(calls) == ["dense", "moe"]     # once per kind, not per layer
    assert prof.layers[0].w_frac == pytest.approx(0.21)
    assert prof.layers[1].w_frac == pytest.approx(0.47)
    assert prof.layers[0].w_frac != prof.layers[1].w_frac
    # a kind whose timing is unavailable falls back analytically PER LAYER
    monkeypatch.setattr(
        P, "measure_w_frac",
        lambda c, seq=128, iters=5, kind="dense":
        0.21 if kind == "dense" else None)
    prof2 = P.profile_arch(cfg, seq=64)
    assert prof2.layers[0].w_frac == pytest.approx(0.21)
    assert prof2.layers[1].w_frac == pytest.approx(
        P.profile_arch(dataclasses.replace(cfg, profile_w_frac="analytic"),
                       seq=64).layers[1].w_frac)
    # the real MoE proxy: a timed fraction in (0, 1) or a clean fallback
    wf = real_measure(cfg, seq=16, iters=1, kind="moe")
    assert wf is None or 0.0 < wf < 1.0
    assert real_measure(get_config("llama3.2-1b"), kind="moe") is None
    # the ssm scan proxy needs an ssm block in the config; truly
    # unknown kinds still raise
    assert real_measure(cfg, kind="ssm") is None
    with pytest.raises(ValueError, match="kind"):
        real_measure(cfg, kind="conv")


def test_measured_w_frac_ssm_scan_proxy():
    """The SSM proxy (associative-scan mixer: the scan's vjp carries no
    dL/dw, so W is the projections only) times a real fraction; pure
    SSM trunks route to it while hybrid attn+ssm trunks stay on the
    dense proxy (their per-layer mix isn't separable by kind)."""
    from repro.configs import get_config
    from repro.core import profiler as P
    cfg = get_config("mamba2-2.7b").reduced(d_model=64)
    assert [P.layer_kind(cfg, i) for i in range(cfg.n_layers)] == \
        ["ssm"] * cfg.n_layers
    # timed fraction or a clean None fallback (the timer rejects
    # noise-dominated splits) — same contract as the moe proxy above
    wf = P.measure_w_frac(cfg, seq=16, iters=1, kind="ssm")
    assert wf is None or 0.0 < wf < 1.0
    hybrid = get_config("hymba-1.5b").reduced(d_model=64)
    assert P.layer_kind(hybrid, 0) == "dense"


# ---------------------------------------------------------------------------
# Acceptance: skewed 4-device cluster, cost-shaped beats uniform-scalar.
# ---------------------------------------------------------------------------

def _skewed_fixture():
    """A 2-fast/2-slow chain over 7 balanced layers: the granularity the
    partitioner cannot even out, so per-stage costs stay skewed (the
    fixture ``benchmarks/paper_tables.table_hetero`` reproduces)."""
    prof = NetworkProfile("balanced7", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(7)), unit="sample")
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0)
    slow = dataclasses.replace(fast, name="slow", peak_flops=50e12)
    return prof, heterogeneous_cluster([fast, slow, fast, slow])


def test_skewed_cluster_cost_shaped_beats_uniform_scalar():
    """ISSUE 5 acceptance: on a skewed 4-device cluster (uneven layers,
    mixed profiled w_frac, one fast device, a binding peak-live cap) the
    cost-shaped explorer's zb-auto plan strictly beats the BEST
    uniform-scalar plan — generously defined as the better of the
    scalar explorer's pick and the max-scalar-built zb-auto table at
    the same cap — all replayed at the true per-device durations
    (simulator-pinned), not compared through their own cost models."""
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0)
    flops = [1e12, 4e12, 1e12, 4e12, 2e12, 2e12, 2e12, 1e12, 4e12]
    wfr = [0.5, 0.15, 0.3, 0.7, 0.5, 0.5, 0.7, 0.5, 0.7]
    prof = NetworkProfile("skewed9", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=f, bytes_weights=1e6,
                     bytes_act_out=1e9, w_frac=w)
        for i, (f, w) in enumerate(zip(flops, wfr))), unit="sample")
    cl = heterogeneous_cluster(
        [dataclasses.replace(fast, peak_flops=p)
         for p in (40e12, 40e12, 100e12, 40e12)])
    M, N, K = 8, 4, 5
    r_vec = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                    candidate_Vs=(), mem_limit=K)
    r_sca = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                    candidate_Vs=(), mem_limit=K, hetero=False)
    assert r_vec.schedule == "ZB-AUTO", r_vec.schedule
    costs = r_vec.plan.cost_vector()
    assert not costs.uniform             # the skew survives partitioning

    # simulator pin: the explorer's reported time IS the replay of the
    # cost-shaped table it chose
    shaped = SP.build_zb_auto(M, N, costs=(list(costs.F), list(costs.B),
                                           list(costs.W)), mem_limit=K)
    t_vec = simulate(shaped, M, N, list(costs.F), list(costs.B_full),
                     0.0, w_frac=list(costs.w_frac)).makespan
    assert r_vec.minibatch_time == pytest.approx(t_vec, rel=1e-12)

    # the BEST uniform-scalar plan: the scalar explorer's pick AND the
    # max-scalar-built zb-auto table, each replayed at the true
    # durations of its own partition
    sc = r_sca.plan.cost_vector()
    Fb, Bb = r_sca.plan.bottleneck_FB()
    cands = [SP.build_zb_auto(M, N, (Fb, Bb / 2, Bb / 2), mem_limit=K)]
    if SP.canonical_name(r_sca.schedule) != "zb-auto":
        # the legacy name keeps its builder kwargs (FBP-AS's doubled
        # warm-up), so build from it directly
        cands.append(SP.build_schedule(r_sca.schedule, M, N, 1))
    t_uniform = min(
        simulate(p, M, N, list(sc.F), list(sc.B_full), 0.0,
                 w_frac=list(sc.w_frac)).makespan for p in cands)
    # strictly better — by several percent, not float noise
    assert t_vec < t_uniform * 0.995, (t_vec, t_uniform)


def test_skewed_cluster_autoplan_heterogeneous_devices():
    """auto_plan over an explicit heterogeneous device list fixes the
    stage count and returns a valid cost-shaped plan."""
    from repro.configs import get_config
    from repro.core.autoplan import auto_plan
    from repro.core.hardware import TPU_V5E
    cfg = get_config("llama3.2-1b")
    slow = dataclasses.replace(TPU_V5E, name="tpu_slow",
                               peak_flops=TPU_V5E.peak_flops / 2)
    p = auto_plan(cfg, global_batch=256, seq_len=2048, model_axis=16,
                  devices=[TPU_V5E, slow, TPU_V5E, slow])
    assert p.stages == 4
    assert p.stages * p.tensor == 16
    assert p.predicted_step_time > 0


# ---------------------------------------------------------------------------
# V > 1 and sync candidates route through the scheduled replay (the old
# code fell through to the scalar closed forms even on skewed clusters).
# ---------------------------------------------------------------------------

def test_new_hetero_forms_uniform_delegation():
    """The interleaved and sync hetero forms delegate bit-exactly to
    their scalar closed forms on uniform vectors (SR included — the
    sync forms put SR on the critical path)."""
    M, N, F, B, SR, a, w = 8, 4, 1.3, 2.6, 0.2, 4.0, 10.0
    costs = SP.StageCosts.uniform_costs(N, F, B, SR=SR)
    pairs = [
        (S.eval_1f1b_sno_hetero(M, N, costs, a, w),
         S.eval_1f1b_sno(M, N, F, B, SR, a, w)),
        (S.eval_1f1b_so_hetero(M, N, costs, a, w),
         S.eval_1f1b_so(M, N, F, B, SR, a, w)),
        (S.eval_1f1b_interleaved_hetero(M, N, costs, a, w, V=2),
         S.eval_1f1b_interleaved(M, N, F, B, SR, a, w, V=2)),
        (S.eval_1f1b_interleaved_memlean_hetero(M, N, costs, a, w, V=2),
         S.eval_1f1b_interleaved_memlean(M, N, F, B, SR, a, w, V=2)),
    ]
    for het, uni in pairs:
        assert het == uni, (het.name, het, uni)


def test_hetero_sync_and_interleaved_differential_vs_simulate_costs():
    """On the ``table_hetero`` skew (balanced 7-layer chain over a
    fast/slow/fast/slow cluster — granularity the partitioner cannot
    even out) the new forms report exactly the simulator's scheduled
    makespan: SNO under ``blocking``, SO under ``latency`` (each hop
    its OWN SR), interleaved V>1 the free-comm replay of the V-chunk
    table.  The sync replays sit at or below the worst-hop closed form
    the old fallthrough reported."""
    prof, cl = _skewed_fixture()
    # a slow middle link so the per-hop SR vector is genuinely uneven
    devs = [dataclasses.replace(d, link_bandwidth=10e9 if i == 1 else
                                d.link_bandwidth)
            for i, d in enumerate(cl.devices)]
    cl = heterogeneous_cluster(devs)
    M = 8
    r = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                candidate_Vs=())
    costs = r.plan.cost_vector()
    N = costs.n
    assert not costs.uniform and len(set(costs.sr_hops)) > 1
    a, w = 1.0, 1.0

    sno = S.eval_1f1b_sno_hetero(M, N, costs, a, w)
    assert sno.minibatch_time == pytest.approx(
        simulate_costs("1f1b", M, N, costs, comm="blocking").makespan,
        rel=1e-12)
    so = S.eval_1f1b_so_hetero(M, N, costs, a, w)
    assert so.minibatch_time == pytest.approx(
        simulate_costs("1f1b", M, N, costs, comm="latency").makespan,
        rel=1e-12)
    assert so.minibatch_time <= sno.minibatch_time + 1e-9

    # the old fallthrough reported the scalar closed form at bottleneck
    # (F, B) and the WORST-hop SR on every hop — on this skew it
    # under-counts the scheduled stalls the replay surfaces, the
    # observable the routing fix changes
    F, B = r.plan.bottleneck_FB()
    worst = max(costs.sr_hops)
    old = S.eval_1f1b_sno(M, N, F, B, worst, a, w)
    assert abs(sno.minibatch_time - old.minibatch_time) > 1e-6 * \
        old.minibatch_time

    for V in (2, 4):
        ev = S.eval_1f1b_interleaved_hetero(M, N, costs, a, w, V=V)
        ref = simulate("1f1b-interleaved", M, N, list(costs.F),
                       list(costs.B_full), 0.0, V=V, comm="free",
                       w_frac=list(costs.w_frac)).makespan
        assert ev.minibatch_time == pytest.approx(ref, rel=1e-12)
        assert ev.V == V
    ml = S.eval_1f1b_interleaved_memlean_hetero(M, N, costs, a, w, V=2)
    refml = simulate("1f1b-interleaved-memlean", M, N, list(costs.F),
                     list(costs.B_full), 0.0, V=2, comm="free",
                     w_frac=list(costs.w_frac)).makespan
    assert ml.minibatch_time == pytest.approx(refml, rel=1e-12)
    with pytest.raises(ValueError, match="M % N"):
        S.eval_1f1b_interleaved_memlean_hetero(M + 1, N, costs, a, w)


def test_explorer_routes_sync_candidates_through_replay():
    """A sync-only (GPU-like) skewed cluster: the explorer's reported
    time for its sync pick IS the per-hop comm-model replay of the
    1F1B table, not the worst-hop scalar closed form."""
    prof, cl = _skewed_fixture()
    devs = [dataclasses.replace(d, async_capable=False,
                                link_bandwidth=10e9 if i == 1 else
                                d.link_bandwidth)
            for i, d in enumerate(cl.devices)]
    cl = heterogeneous_cluster(devs)
    M = 8
    r = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                candidate_Vs=())
    assert r.schedule in ("1F1B-SNO", "1F1B-SO")
    costs = r.plan.cost_vector()
    assert not costs.uniform
    comm = "blocking" if r.schedule == "1F1B-SNO" else "latency"
    ref = simulate_costs("1f1b", M, costs.n, costs, comm=comm).makespan
    assert r.minibatch_time == pytest.approx(ref, rel=1e-12)


def test_explorer_routes_interleaved_candidates_through_replay():
    """An 8-layer skewed async cluster admits V=2 interleave over N=4:
    whatever the explorer picks, every V>1 candidate it evaluated must
    carry the scheduled (replayed) makespan — pinned by recomputing the
    pick's eval from its own partition vector when the pick is
    interleaved, and by checking the hetero form is what the explorer's
    routing produces for a forced V>1 evaluation either way."""
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0)
    slow = dataclasses.replace(fast, name="slow", peak_flops=50e12)
    prof = NetworkProfile("balanced8", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(8)), unit="sample")
    cl = heterogeneous_cluster([fast, slow, fast, slow])
    M = 8
    r = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                candidate_Vs=(2,))
    if r.V > 1:
        costs = r.plan.cost_vector()
        fn = (S.eval_1f1b_interleaved_memlean_hetero
              if r.schedule == "1F1B-I-ML"
              else S.eval_1f1b_interleaved_hetero)
        a = r.plan.max_boundary_act()
        w = max(c.weight_bytes for c in r.plan.device_costs())
        ev = fn(M, costs.n, costs, a, w, V=r.V)
        assert r.sched_eval.minibatch_time == pytest.approx(
            ev.minibatch_time, rel=1e-12)


def test_explorer_hetero_false_reproduces_scalar_collapse():
    """The legacy path is preserved bit-for-bit: hetero=False evaluates
    the bottleneck scalars through the uniform closed forms."""
    prof, cl = _skewed_fixture()
    M = 8
    r = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                candidate_Vs=(), hetero=False)
    F, B = r.plan.bottleneck_FB()
    SR = max((max(c.comm_in, c.comm_out) for c in r.plan.stage_costs),
             default=0.0)
    a = r.plan.max_boundary_act()
    w = max(c.weight_bytes for c in r.plan.device_costs())
    ev = S.SCHEDULES[r.schedule](M, 4, F, B, SR, a, w)
    assert r.minibatch_time == pytest.approx(ev.minibatch_time, rel=1e-12)
