"""Auto-planner: the explorer's choices respect architecture constraints
and scale intuition."""
import pytest

from repro.configs import get_config
from repro.core.autoplan import auto_plan


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b",
                                  "deepseek-v2-lite-16b", "whisper-base",
                                  "gemma3-1b", "qwen3-1.7b"])
def test_autoplan_valid_factorisation(arch):
    cfg = get_config(arch)
    p = auto_plan(cfg, global_batch=256, seq_len=4096)
    assert p.stages * p.tensor == 16
    assert p.stages <= cfg.n_layers
    if cfg.ssm is not None:
        assert p.tensor == 1          # SSM blocks are never tensor-sharded
    else:
        assert cfg.n_heads % p.tensor == 0 or p.tensor == 1
    assert p.n_microbatches >= 1
    assert p.predicted_step_time > 0


def test_autoplan_ssm_forces_deep_pipeline():
    p = auto_plan(get_config("mamba2-2.7b"), global_batch=256, seq_len=4096)
    assert p.tensor == 1 and p.stages == 16


def test_autoplan_shallow_model_avoids_deep_pipeline():
    p = auto_plan(get_config("whisper-base"), global_batch=256, seq_len=4096)
    assert p.stages <= 4              # only 6 layers


def test_autoplan_m_divides_local_batch():
    p = auto_plan(get_config("llama3.2-1b"), global_batch=256, seq_len=4096,
                  data_axis=16)
    assert (256 // 16) % p.n_microbatches == 0
