"""Multi-device (8 virtual CPU cores) pipeline tests, via subprocess so the
main pytest process keeps its single device (jax locks device count at
first init).

The central claim under test is the paper's: intra-batch pipeline
parallelism preserves synchronous-training semantics — pipeline loss and
gradients equal the single-device reference across data x stage x tensor
sharding, for every architecture family.
"""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "harness_pipe.py")


def run_case(*args, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, HARNESS, *args], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"{args}:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}"
    assert "OK" in r.stdout, r.stdout


@pytest.mark.parametrize("arch", [
    "llama3.2-1b",            # dense GQA, data x stage x tensor
    "deepseek-v2-lite-16b",   # MoE + MLA, experts over tensor
    "mamba2-2.7b",            # pure SSM
    "hymba-1.5b",             # hybrid attn+ssm
    "whisper-base",           # enc-dec
    "gemma3-1b",              # sliding window, kv-replicated tensor
    "qwen2-vl-7b",            # M-RoPE
])
def test_pipeline_grad_equivalence(arch):
    run_case("train_equivalence", arch)


def test_pipeline_grad_equivalence_fsdp():
    run_case("train_equivalence", "llama3.2-1b", "2", "2", "1")


def test_moe_expert_parallel_all_to_all():
    run_case("moe_ep_data")


@pytest.mark.parametrize("arch", [
    "llama3.2-1b", "mamba2-2.7b", "deepseek-v2-lite-16b", "gemma3-1b"])
def test_pipelined_serve_equivalence(arch):
    run_case("serve_equivalence", arch)


def test_end_to_end_training_loss_decreases():
    run_case("train_loss_decreases", "llama3.2-1b", timeout=540)


def test_serve_driver_end_to_end():
    run_case("serve_driver", "llama3.2-1b")


@pytest.mark.parametrize("stages,tensor,virtual,microbatches", [
    (2, 2, 2, 2),     # minimal interleave, M == S ring boundary case
    (2, 2, 4, 4),     # deep interleave
    (4, 1, 2, 4),     # 4-stage ring, 2 passes
])
def test_interleaved_1f1b_grad_equivalence(stages, tensor, virtual,
                                           microbatches):
    """1F1B-I (virtual-stage interleaving): loss/grads must match both the
    V=1 pipeline and the single-device reference."""
    run_case("interleaved_equivalence", "llama3.2-1b", str(stages),
             str(tensor), str(virtual), str(microbatches))


@pytest.mark.parametrize("stages,tensor,virtual,microbatches", [
    (2, 2, 2, 2),     # M == S: tight ring, every return consumed directly
    (4, 1, 2, 4),     # 4-stage ring, Megatron group order, M == S
    (2, 2, 2, 4),     # M == 2S: two micro-batch groups per chunk cycle
])
def test_memlean_1f1b_grad_equivalence(stages, tensor, virtual,
                                       microbatches):
    """1f1b-interleaved-memlean executes on the runtime ring with NO
    [M, ...] return buffer and must stay grad-equivalent to the V=1
    pipeline and the single-device reference."""
    run_case("interleaved_equivalence", "llama3.2-1b", str(stages),
             str(tensor), str(virtual), str(microbatches),
             "1f1b-interleaved-memlean")


def test_interleaved_fsdp_grad_equivalence():
    """fsdp x virtual>1: the [S, V, Lc] stacking shifts the all_gather
    dims (fsdp_scan_dims offsets); gradients must match the reference."""
    run_case("interleaved_equivalence", "llama3.2-1b", "2", "2", "2", "4",
             "auto", "1")
    run_case("interleaved_equivalence", "llama3.2-1b", "2", "2", "2", "2",
             "1f1b-interleaved-memlean", "1")


@pytest.mark.parametrize("stages,tensor,microbatches,schedules", [
    (2, 2, 4, ("gpipe", "1f1b", "dapple", "zb_h1")),   # two-op + zb-h1
    (4, 1, 4, ("gpipe", "dapple", "zb_h1")),           # deep ring, warm-up 4
    (2, 2, 4, ("zb_h2", "zb_auto", "zb_auto:2")),      # zero-bubble family
    (4, 1, 4, ("zb_h2", "zb_auto:4")),                 # deep ring zb; capped
])
def test_backward_tick_schedules_grad_equivalence(stages, tensor,
                                                  microbatches, schedules):
    """First-class backward ticks: every V=1 builder — gpipe's
    all-F-then-all-B, 1f1b/dapple's early backward, the zero-bubble
    family's split input-/weight-gradient ticks (zb_h1, zb_h2, zb_auto
    both unbounded and under a mem_limit cap, where the tick table and
    the residual stash size change) — must produce loss/grads equal to
    the single-device reference on 8 fake devices.  Together with the
    interleaved cases above this covers all ring builders."""
    run_case("schedule_equivalence", "llama3.2-1b", str(stages), str(tensor),
             str(microbatches), *schedules, timeout=540)


@pytest.mark.parametrize("stages,tensor,microbatches,schedules", [
    (2, 2, 4, ("gpipe", "1f1b", "dapple")),            # two-op family
    (2, 2, 4, ("zb-h1", "zb-h2", "zb-auto")),          # zero-bubble family
    (2, 2, 4, ("1f1b-interleaved",
               "1f1b-interleaved-memlean")),           # V=2 ring returns
    (4, 1, 4, ("1f1b", "zb-h1", "zb-auto")),           # deep ring
    (4, 1, 4, ("gpipe", "1f1b-interleaved",
               "1f1b-interleaved-memlean")),           # deep ring, V=2
])
def test_stream_runtime_grad_equivalence(stages, tensor, microbatches,
                                         schedules):
    """Instruction-stream runtime (runtime='stream'): loss/grads must be
    bit-equal to the tick runtime (identical compiled op sequence — the
    gated rings skip only dead transfers) and grad-equal to the
    single-device reference, for every ring builder at 2 and 4 stages."""
    run_case("stream_equivalence", "llama3.2-1b", str(stages), str(tensor),
             str(microbatches), *schedules, timeout=540)


@pytest.mark.parametrize("schedules", [
    ("gpipe", "1f1b", "dapple"),                       # two-op family
    ("zb-h1", "zb-h2", "zb-auto"),                     # zero-bubble family
    ("1f1b-interleaved", "1f1b-interleaved-memlean"),  # V=2 ring
])
def test_dp_overlap_grad_sync_bit_equality(schedules):
    """Bubble-filling gradient sync (grad_sync='overlap'): the AR
    bucket ops the builder schedules into the drain must leave
    loss/grads bit-equal to the trailing sync-at-end psum they replace,
    on a 2(data) x 4(stage) mesh, for every ring builder."""
    run_case("dp_overlap", "llama3.2-1b", "4", "1", "4", *schedules,
             timeout=540)


def test_tp_grad_equivalence_both_runtimes():
    """Uniform-TP execution on the real tensor axis: tp=2 x {ticks,
    stream} x {1f1b, zb-h1} gradients must equal the single-device
    reference (ticks == stream bit-equal) — the 3D planner's uniform
    (dp, tp) candidates are executable plans, not just analytic
    entries."""
    run_case("tp_equivalence", "llama3.2-1b", timeout=540)


def test_2bw_stale_by_one_weight_updates():
    """PipeDream-2BW double-buffered weights (grad_sync='2bw'): the
    parameter trajectory must equal the host-side stale-by-one replay
    of the run's own gradient snapshots — step 0 applies its own grads,
    step k applies step k-1's — and must differ from the non-stale
    replay."""
    run_case("two_bw", "llama3.2-1b", timeout=540)


@pytest.mark.parametrize("groups", ["2", "4"])
def test_ar_groups_bucket_split_bit_equality(groups):
    """Finer-grained AR buckets (ar_groups=G, released as each layer
    group's W retires mid-drain) must leave loss/grads bit-equal to the
    single-bucket overlapped sync — a pure scheduling change."""
    run_case("ar_groups", "llama3.2-1b", "2", groups, timeout=540)


@pytest.mark.parametrize("virtual", ["1", "2"])
def test_pos3_rides_the_ppermute_ring(virtual):
    """Regression (pre-seed defect): per-micro-batch DISTINCT M-RoPE
    positions must follow their micro-batch through the ring — stage s
    works on micro-batch (t - s) % M, not stage 0's t % M."""
    if virtual == "1":
        run_case("pos3_ring")                       # 4-stage, V=1
    else:
        run_case("pos3_ring", "qwen2-vl-7b", "2", "2", "2", "4")


@pytest.mark.parametrize("arch,stages,tensor,virtual,microbatches,schedule", [
    ("llama3.2-1b", 2, 2, 2, 4, "auto"),       # streaming, park buffer
    ("llama3.2-1b", 2, 2, 2, 2,
     "1f1b-interleaved-memlean"),              # memlean, no park buffer
    ("llama3.2-1b", 2, 2, 4, 4, "auto"),       # deep interleave
    ("mamba2-2.7b", 2, 1, 2, 2, "auto"),       # ssm conv/state cache chunks
])
def test_interleaved_prefill_equivalence(arch, stages, tensor, virtual,
                                         microbatches, schedule):
    """Pipelined prefill on an interleaved (V>1) plan: two-segment prefill
    through the chunk-stacked cache must match the single-device
    reference."""
    run_case("prefill_equivalence", arch, str(stages), str(tensor),
             str(virtual), str(microbatches), schedule)


def test_interleaved_decode_equivalence():
    """One-token decode on an interleaved (V>1) plan — formerly a
    NotImplementedError — must match the single-device reference through
    the chunk-stacked [S, V, Lc, ...] cache."""
    run_case("interleaved_decode", "llama3.2-1b")


def test_continuous_batching_serve():
    """Open-loop continuous batching: staggered arrivals admitted into
    slots of a paged KV cache, chunked prefill mixed with running
    decodes in single steps; every request's tokens must be
    bit-identical to its solo single-device reference."""
    run_case("serve_continuous", "llama3.2-1b", timeout=540)


def test_pod_as_stage_pipeline():
    """Beyond-paper: pipeline depth spans the pod axis (pipeline over DCN);
    gradients must still match the reference."""
    run_case("pod_stage_equivalence")


def test_gated_serve_equivalence():
    """Valid-tick gating (lax.cond-skip of fill/drain ticks) must not
    change decode results."""
    run_case("gated_serve", "mamba2-2.7b")
    run_case("gated_serve", "llama3.2-1b")


def test_elastic_kill_and_resume():
    """The survive loop: an 8-stage run checkpoints periodically, dies
    mid-run by fault injection, and resumes on 4 stages x 2 virtual
    chunks (half the devices) after a host-side checkpoint reshard —
    loss trajectory bit-equal to the uninterrupted 8-stage reference."""
    run_case("elastic_resume", "llama3.2-1b", timeout=540)


def test_elastic_drift_triggers_replan():
    """Injected per-stage cost skew trips the drift monitor and fires a
    budget-bounded replan recommendation mid-run."""
    run_case("elastic_drift", "llama3.2-1b", timeout=540)
