"""Multi-device (8 virtual CPU cores) pipeline tests, via subprocess so the
main pytest process keeps its single device (jax locks device count at
first init).

The central claim under test is the paper's: intra-batch pipeline
parallelism preserves synchronous-training semantics — pipeline loss and
gradients equal the single-device reference across data x stage x tensor
sharding, for every architecture family.
"""
import os
import subprocess
import sys

import pytest

HARNESS = os.path.join(os.path.dirname(__file__), "harness_pipe.py")


def run_case(*args, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, HARNESS, *args], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"{args}:\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}"
    assert "OK" in r.stdout, r.stdout


@pytest.mark.parametrize("arch", [
    "llama3.2-1b",            # dense GQA, data x stage x tensor
    "deepseek-v2-lite-16b",   # MoE + MLA, experts over tensor
    "mamba2-2.7b",            # pure SSM
    "hymba-1.5b",             # hybrid attn+ssm
    "whisper-base",           # enc-dec
    "gemma3-1b",              # sliding window, kv-replicated tensor
    "qwen2-vl-7b",            # M-RoPE
])
def test_pipeline_grad_equivalence(arch):
    run_case("train_equivalence", arch)


def test_pipeline_grad_equivalence_fsdp():
    run_case("train_equivalence", "llama3.2-1b", "2", "2", "1")


def test_moe_expert_parallel_all_to_all():
    run_case("moe_ep_data")


@pytest.mark.parametrize("arch", [
    "llama3.2-1b", "mamba2-2.7b", "deepseek-v2-lite-16b", "gemma3-1b"])
def test_pipelined_serve_equivalence(arch):
    run_case("serve_equivalence", arch)


def test_end_to_end_training_loss_decreases():
    run_case("train_loss_decreases", "llama3.2-1b", timeout=540)


def test_serve_driver_end_to_end():
    run_case("serve_driver", "llama3.2-1b")


@pytest.mark.parametrize("stages,tensor,virtual,microbatches", [
    (2, 2, 2, 2),     # minimal interleave, M == S ring boundary case
    (2, 2, 4, 4),     # deep interleave
    (4, 1, 2, 4),     # 4-stage ring, 2 passes
])
def test_interleaved_1f1b_grad_equivalence(stages, tensor, virtual,
                                           microbatches):
    """1F1B-I (virtual-stage interleaving): loss/grads must match both the
    V=1 pipeline and the single-device reference."""
    run_case("interleaved_equivalence", "llama3.2-1b", str(stages),
             str(tensor), str(virtual), str(microbatches))


def test_pod_as_stage_pipeline():
    """Beyond-paper: pipeline depth spans the pod axis (pipeline over DCN);
    gradients must still match the reference."""
    run_case("pod_stage_equivalence")


def test_gated_serve_equivalence():
    """Valid-tick gating (lax.cond-skip of fill/drain ticks) must not
    change decode results."""
    run_case("gated_serve", "mamba2-2.7b")
    run_case("gated_serve", "llama3.2-1b")
