"""Serve-runtime buffer regressions (single device).

The streaming 1F1B-I return buffer (stage-0 parked ring returns) used to
be allocated FULL-SIZE on every device: the scan carry is SPMD-uniform,
so write-masking the parks to stage 0 never shrank the allocation.  It
is now feature-sharded over the stage axis — ``psum_scatter`` on park,
``all_gather`` on read, both gated to the scheduled park/read ticks —
so each device holds 1/S of it.  These tests pin the S-fold per-device
byte drop and the engagement predicate; the numerics are covered by the
multi-device prefill/serve equivalence suites.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.pipeline import runtime as RT


def _bytes(tree):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


def test_retbuf_sharded_bytes_drop_by_stage_count():
    M_, mb, T, d = 8, 2, 32, 128
    inj = jnp.zeros((M_, mb, T, d))
    for S in (2, 4, 8):
        full = jax.eval_shape(lambda q: RT._retbuf_init(q, S, False), inj)
        shard = jax.eval_shape(lambda q: RT._retbuf_init(q, S, True), inj)
        assert _bytes(full) == S * _bytes(shard), S
        assert jax.tree.leaves(shard)[0].shape == (M_, mb, T, d // S)
        assert jax.tree.leaves(full)[0].shape == (M_, mb, T, d)


def test_retbuf_shard_predicate():
    cfg = get_config("llama3.2-1b").reduced(d_model=128)
    assert RT._shard_retbuf(cfg, 4, "stage")
    assert not RT._shard_retbuf(cfg, 1, "stage")            # no pipeline
    assert not RT._shard_retbuf(cfg, 4, ("pod", "stage"))   # fused DCN axis
    odd = dataclasses.replace(cfg, d_model=130)
    assert not RT._shard_retbuf(odd, 4, "stage")            # 130 % 4 != 0
    assert RT._shard_retbuf(odd, 2, "stage")


def test_retbuf_dict_injection_shards_every_leaf():
    # audio-family injection is a dict; every leaf's feature dim shards
    inj = dict(h_dec=jnp.zeros((4, 2, 16, 128)),
               h_enc=jnp.zeros((4, 2, 8, 128)))
    shard = jax.eval_shape(lambda q: RT._retbuf_init(q, 4, True), inj)
    assert shard["h_dec"].shape == (4, 2, 16, 32)
    assert shard["h_enc"].shape == (4, 2, 8, 32)
    full = jax.eval_shape(lambda q: RT._retbuf_init(q, 4, False), inj)
    assert _bytes(full) == 4 * _bytes(shard)
