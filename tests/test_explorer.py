"""End-to-end BaPipe exploration: the paper's qualitative results."""
import pytest

from repro.core.explorer import explore, gpipe_time, pipedream_time
from repro.core.hardware import (V100, VCU118, VCU129, heterogeneous_cluster,
                                 homogeneous_cluster)
from repro.core.profiler import (profile_gnmt, profile_resnet50,
                                 profile_vgg16, profile_arch)
from repro.configs import get_config


def test_resnet50_explorer_prefers_dp_on_8_v100():
    """Paper Table 3: 'both BaPipe and PipeDream have explored that the
    best partition is DP' for ResNet-50 (activation traffic > weight
    traffic)."""
    r = explore(profile_resnet50(), homogeneous_cluster(V100, 8), 128)
    assert r.mode == "data_parallel"


def test_vgg16_and_gnmt_prefer_pipeline():
    for prof, mb in ((profile_vgg16(), 128), (profile_gnmt(8), 256)):
        r = explore(prof, homogeneous_cluster(V100, 4), mb)
        assert r.mode == "pipeline", prof.name
        assert r.speedup_over_dp > 1.0


def test_gpu_cluster_gets_sync_schedule():
    r = explore(profile_vgg16(), homogeneous_cluster(V100, 4), 128)
    assert r.schedule in ("1F1B-SNO", "1F1B-SO")


def test_fpga_cluster_gets_async_schedule():
    r = explore(profile_resnet50(), homogeneous_cluster(VCU118, 4), 128)
    if r.mode == "pipeline":
        assert r.schedule in ("1F1B-AS", "FBP-AS")


def test_heterogeneous_fpga_cluster_explores():
    cl = heterogeneous_cluster([VCU129, VCU129, VCU118, VCU118])
    r = explore(profile_resnet50(), cl, 128)
    assert r.minibatch_time < float("inf")


def test_pipeline_memory_scales_down_with_stages():
    """Paper Table 4: pipeline supports bigger models as N grows (per-stage
    weights shrink); DP stays flat."""
    prof = profile_gnmt(16)
    mems = []
    for n in (2, 4, 8):
        r = explore(prof, homogeneous_cluster(V100, n), 64,
                    consider_dp=False)
        assert r.plan is not None
        mems.append(max(c.weight_bytes for c in r.plan.stage_costs))
    assert mems[0] > mems[1] > mems[2]


def test_baseline_models():
    gp_t, gp_mem = gpipe_time(profile_vgg16(), homogeneous_cluster(V100, 4),
                              128, M=8)
    pd_t, pd_mem = pipedream_time(profile_vgg16(),
                                  homogeneous_cluster(V100, 4), 128)
    assert gp_t > 0 and pd_t > 0
    # GPipe stores all M micro-batch activations; PipeDream stashes weights
    assert max(gp_mem) > 0 and max(pd_mem) > 0


def test_explore_assigned_arch_profiles():
    """BaPipe's explorer consumes the assigned-architecture profiles too."""
    for arch in ("llama3.2-1b", "mamba2-2.7b", "deepseek-v2-lite-16b"):
        prof = profile_arch(get_config(arch), seq=2048)
        r = explore(prof, homogeneous_cluster(V100, 8), 64)
        assert r.minibatch_time < float("inf")
        assert r.feasible
