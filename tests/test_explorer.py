"""End-to-end BaPipe exploration: the paper's qualitative results."""
import dataclasses

import pytest

from repro.core.explorer import explore, gpipe_time, pipedream_time
from repro.core.hardware import (TPU_V5E, V100, VCU118, VCU129,
                                 heterogeneous_cluster, homogeneous_cluster)
from repro.core.profiler import (profile_gnmt, profile_resnet50,
                                 profile_vgg16, profile_arch)
from repro.configs import get_config


def test_resnet50_explorer_prefers_dp_on_8_v100():
    """Paper Table 3: 'both BaPipe and PipeDream have explored that the
    best partition is DP' for ResNet-50 (activation traffic > weight
    traffic)."""
    r = explore(profile_resnet50(), homogeneous_cluster(V100, 8), 128)
    assert r.mode == "data_parallel"


def test_vgg16_and_gnmt_prefer_pipeline():
    for prof, mb in ((profile_vgg16(), 128), (profile_gnmt(8), 256)):
        r = explore(prof, homogeneous_cluster(V100, 4), mb)
        assert r.mode == "pipeline", prof.name
        assert r.speedup_over_dp > 1.0


def test_gpu_cluster_gets_sync_schedule():
    r = explore(profile_vgg16(), homogeneous_cluster(V100, 4), 128)
    assert r.schedule in ("1F1B-SNO", "1F1B-SO")


def test_fpga_cluster_gets_async_schedule():
    from repro.core.schedules import ASYNC_SCHEDULES
    r = explore(profile_resnet50(), homogeneous_cluster(VCU118, 4), 128)
    if r.mode == "pipeline":
        assert r.schedule in ASYNC_SCHEDULES


def test_heterogeneous_fpga_cluster_explores():
    cl = heterogeneous_cluster([VCU129, VCU129, VCU118, VCU118])
    r = explore(profile_resnet50(), cl, 128)
    assert r.minibatch_time < float("inf")


def test_pipeline_memory_scales_down_with_stages():
    """Paper Table 4: pipeline supports bigger models as N grows (per-stage
    weights shrink); DP stays flat."""
    prof = profile_gnmt(16)
    mems = []
    for n in (2, 4, 8):
        r = explore(prof, homogeneous_cluster(V100, n), 64,
                    consider_dp=False)
        assert r.plan is not None
        mems.append(max(c.weight_bytes for c in r.plan.stage_costs))
    assert mems[0] > mems[1] > mems[2]


def test_interleaved_picked_when_bubble_dominates():
    """With few micro-batches (bubble dominates), ample memory and
    *balanced* layers, the explorer must interleave: 1F1B-I with V > 1
    beats every V=1 schedule — including ZB-H1, whose zero-bubble saving
    ``(N-1)B/2`` is smaller than the bubble shrink from V.  (On an
    UNbalanced profile like GNMT the N*V-chunk partition has a worse
    bottleneck and ZB-H1 can legitimately win — see
    test_zb_h1_wins_unbalanced_bubble_fixture.)"""
    from repro.core.profiler import LayerProfile, NetworkProfile
    prof = NetworkProfile("balanced", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(16)), unit="sample")
    roomy = dataclasses.replace(TPU_V5E, memory_capacity=1e15,
                                link_bandwidth=1e13, async_capable=True)
    r = explore(prof, homogeneous_cluster(roomy, 4), 8,
                candidate_Ms=[4], consider_dp=False)
    assert r.schedule == "1F1B-I" and r.V > 1, (r.schedule, r.V)
    assert r.plan is not None and r.plan.V == r.V
    # a device owns V non-contiguous chunks covering all layers exactly once
    assert len(r.plan.bounds) == 4 * r.V
    covered = sorted(l for s, e in r.plan.bounds for l in range(s, e))
    assert covered == list(range(prof.n_layers))
    # and the analytic bubble is strictly below the non-interleaved floor
    assert r.sched_eval.bubble_fraction < 3 / (4 + 3)


def test_zero_bubble_family_wins_unbalanced_bubble_fixture():
    """Acceptance: on a bubble-dominated fixture whose layers do NOT
    partition evenly over N*V chunks (GNMT), the explorer lands on the
    zero-bubble family — the V=1 schedules keep the better-balanced
    N-stage partition — specifically on ZB-H2 (first-searched of the
    bubble-free pair; ZB-AUTO ties it).  The simulator replay of the op
    tables confirms the family's strict makespan ladder on the same
    partition: zb-auto <= zb-h2 < zb-h1 < 1f1b."""
    from repro.core.simulator import simulate
    roomy = dataclasses.replace(TPU_V5E, memory_capacity=1e15,
                                link_bandwidth=1e13)
    r = explore(profile_gnmt(16), homogeneous_cluster(roomy, 4), 8,
                candidate_Ms=[4], consider_dp=False)
    assert r.schedule == "ZB-H2", (r.schedule, r.V)
    F, B = r.plan.bottleneck_FB()
    auto = simulate("zb-auto", r.M, 4, F, B, 0.0)
    h2 = simulate("zb-h2", r.M, 4, F, B, 0.0)
    zb = simulate("zb-h1", r.M, 4, F, B, 0.0)
    base = simulate("1f1b", r.M, 4, F, B, 0.0)
    assert auto.makespan <= h2.makespan + 1e-12
    assert h2.makespan < zb.makespan < base.makespan
    assert zb.bubble_fraction() < base.bubble_fraction()
    # ZB-H1's saving is exactly the weight-grad work off the critical
    # path; ZB-H2 additionally removes the drain's (N-1)(B/2)
    assert base.makespan - zb.makespan == pytest.approx(3 * B / 2, rel=1e-9)
    assert zb.makespan - h2.makespan == pytest.approx(3 * B / 2, rel=1e-9)


def test_zero_bubble_family_degrades_with_memory():
    """Acceptance: the zero-bubble family interpolates along the memory
    axis.  On an activation-heavy bubble-dominated fixture (interleaving
    disabled) the explorer lands on the fastest zero-bubble entry whose
    features row fits the devices: roomy memory -> the bubble-free point
    (ZB-H2; unbounded ZB-AUTO ties it at M >= 2N-1), capacity between the
    ZB-H1 and ZB-H2 rows -> ZB-H1 at exactly 1F1B's window."""
    from repro.core.profiler import LayerProfile, NetworkProfile
    from repro.core.hardware import DeviceSpec
    prof = NetworkProfile("acty", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(16)), unit="sample")
    dev = DeviceSpec("async_dev", 100e12, 1e12, 1e15, 1e15,
                     async_capable=True, efficiency=1.0)
    N, M = 4, 8
    roomy = explore(prof, homogeneous_cluster(dev, N), M,
                    candidate_Ms=[M], consider_dp=False, candidate_Vs=())
    assert roomy.schedule == "ZB-H2", (roomy.schedule, roomy.V)
    # per-device rows: zb-auto (unbounded) holds M=8 residuals, zb-h2
    # max(2(N-i+1)-1, i-1+3) = 7 at stage 1, zb-h1 the 1F1B window 4
    cap_h2 = 7.5e9          # admits zb-h2's row, rejects zb-auto's M
    r = explore(prof, homogeneous_cluster(
        dataclasses.replace(dev, memory_capacity=cap_h2), N), M,
        candidate_Ms=[M], consider_dp=False, candidate_Vs=())
    assert r.schedule == "ZB-H2", (r.schedule, r.V)
    assert all(m <= cap_h2 for m in r.per_stage_memory)
    cap_h1 = 4.5e9          # admits only the 1F1B window
    r = explore(prof, homogeneous_cluster(
        dataclasses.replace(dev, memory_capacity=cap_h1), N), M,
        candidate_Ms=[M], consider_dp=False, candidate_Vs=())
    assert r.schedule == "ZB-H1", (r.schedule, r.V)
    assert all(m <= cap_h1 for m in r.per_stage_memory)
    # the mem_limit knob caps ZB-AUTO's row to N residuals, making it
    # feasible again at the tightest tier — and the cost-driven scheduler
    # beats hand-written ZB-H1 there, because a uniform cap of N gives
    # the downstream devices slack the 1F1B staircase (N-i+1) wastes
    r = explore(prof, homogeneous_cluster(
        dataclasses.replace(dev, memory_capacity=cap_h1), N), M,
        candidate_Ms=[M], consider_dp=False, candidate_Vs=(),
        mem_limit=N)
    assert r.schedule == "ZB-AUTO", (r.schedule, r.V)
    assert all(m <= cap_h1 for m in r.per_stage_memory)
    from repro.core.schedules import eval_zb_h1
    F, B = r.plan.bottleneck_FB()
    assert r.minibatch_time < eval_zb_h1(M, N, F, B, 0.0, 1.0,
                                         1.0).minibatch_time


def test_interleaved_rejected_when_memory_exceeded():
    """The V x activation-memory cost must gate infeasible interleaving:
    on an activation-heavy profile with capacity between the V=1 and V>1
    footprints, V>1 candidates are rejected (no spill tier) and the
    explorer falls back to a V=1 schedule."""
    from repro.core.profiler import LayerProfile, NetworkProfile
    from repro.core.hardware import DeviceSpec
    prof = NetworkProfile("acty", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e9, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(16)), unit="sample")
    dev = DeviceSpec("async_dev", 100e12, 1e12, 1e15, 1e13,
                     async_capable=True, efficiency=1.0)
    cl = homogeneous_cluster(dev, 4)
    roomy = explore(prof, cl, 8, candidate_Ms=[4], consider_dp=False)
    assert roomy.schedule == "1F1B-I" and roomy.V > 1      # sanity
    v1 = explore(prof, cl, 8, candidate_Ms=[4], consider_dp=False,
                 candidate_Vs=())
    cap = max(v1.per_stage_memory) * 1.5                   # < V=2 footprint
    tight_cl = homogeneous_cluster(
        dataclasses.replace(dev, memory_capacity=cap), 4)
    r = explore(prof, tight_cl, 8, candidate_Ms=[4], consider_dp=False)
    assert r.feasible
    assert r.V == 1, (r.schedule, r.V)
    assert all(m <= cap for m in r.per_stage_memory)


def test_memlean_selected_when_memory_gates_plain_interleaving():
    """Acceptance: on a memory-gated fixture where plain 1F1B-I is
    rejected (its (V-1)M resident-features term blows the capacity), the
    explorer must land on 1F1B-I-ML — whose (V-1)N term fits — rather
    than falling back to a slower V=1 schedule."""
    from repro.core.profiler import LayerProfile, NetworkProfile
    from repro.core.hardware import DeviceSpec
    # compute-heavy layers on fast links: interleaving is NOT comm-bound,
    # so its smaller bubble wins on time and only memory can gate it
    prof = NetworkProfile("acty", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(16)), unit="sample")
    dev = DeviceSpec("async_dev", 100e12, 1e12, 1e15, 1e15,
                     async_capable=True, efficiency=1.0)
    cl = homogeneous_cluster(dev, 4)
    # roomy: plain streaming 1F1B-I wins (memlean has no edge when memory
    # is free, and the search prefers the incumbent on exact time ties).
    # V=4 so the interleaved bubble (N-1)(F+B)/V beats even ZB-H2's
    # bubble-free-drain (N-1)F floor, which a V=2 interleave no longer
    # does now that the zero-bubble family is searched.
    roomy = explore(prof, cl, 16, candidate_Ms=[16], consider_dp=False,
                    candidate_Vs=(4,))
    assert roomy.schedule == "1F1B-I" and roomy.V == 4, (roomy.schedule,
                                                        roomy.V)
    # capacity between the memlean and streaming footprints: with M=16,
    # N=4, V=4 the stage-1 live rows are 2(N-1)+(V-1)N+1 = 19 (memlean)
    # vs (V-1)M + N = 52 (streaming)
    cap = max(roomy.per_stage_memory) * (30.0 / 52.0)
    tight = homogeneous_cluster(
        dataclasses.replace(dev, memory_capacity=cap), 4)
    r = explore(prof, tight, 16, candidate_Ms=[16], consider_dp=False,
                candidate_Vs=(4,))
    assert r.feasible
    assert r.schedule == "1F1B-I-ML" and r.V == 4, (r.schedule, r.V)
    assert all(m <= cap for m in r.per_stage_memory)
    # and it keeps the interleaved makespan the V=1 fallback cannot reach
    v1 = explore(prof, tight, 16, candidate_Ms=[16], consider_dp=False,
                 candidate_Vs=())
    assert r.minibatch_time < v1.minibatch_time


def test_explorer_still_prefers_dp_for_resnet_with_interleaving_enabled():
    """Adding 1F1B-I to the search space must not flip the paper's
    ResNet-50 'use DP' answer (activation traffic only grows with V)."""
    r = explore(profile_resnet50(), homogeneous_cluster(V100, 8), 128,
                candidate_Vs=(2, 4))
    assert r.mode == "data_parallel"


def test_baseline_models():
    gp_t, gp_mem = gpipe_time(profile_vgg16(), homogeneous_cluster(V100, 4),
                              128, M=8)
    pd_t, pd_mem = pipedream_time(profile_vgg16(),
                                  homogeneous_cluster(V100, 4), 128)
    assert gp_t > 0 and pd_t > 0
    # GPipe stores all M micro-batch activations; PipeDream stashes weights
    assert max(gp_mem) > 0 and max(pd_mem) > 0


def test_explore_assigned_arch_profiles():
    """BaPipe's explorer consumes the assigned-architecture profiles too."""
    for arch in ("llama3.2-1b", "mamba2-2.7b", "deepseek-v2-lite-16b"):
        prof = profile_arch(get_config(arch), seq=2048)
        r = explore(prof, homogeneous_cluster(V100, 8), 64)
        assert r.minibatch_time < float("inf")
        assert r.feasible
