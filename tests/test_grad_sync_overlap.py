"""Bubble-filling gradient sync: the AR op kind end to end.

The tentpole claim: scheduling the data-parallel gradient all-reduce
INTO the pipeline drain (one AR bucket op per device, released at that
device's last compute tick, serialized on the shared data-axis fabric)
costs strictly less wall clock than the sync-at-end baseline whenever
the drain is staggered — and never more.  These tests pin that claim at
every analytic layer: the schedule-plan AR ops and their lowering, the
simulator replay, the closed form, and the explorer's DP-aware ranking.
"""
import dataclasses

import pytest

from repro.core import schedplan as SP
from repro.core.hardware import (DeviceSpec, heterogeneous_cluster,
                                 homogeneous_cluster)
from repro.core.profiler import LayerProfile, NetworkProfile
from repro.core.schedules import (eval_grad_sync, eval_grad_sync_costs,
                                  grad_sync_fifo)
from repro.core.simulator import simulate, simulate_costs
from repro.core.explorer import explore

BUILDERS = ("gpipe", "1f1b", "dapple", "zb-h1", "zb-h2", "zb-auto")
BUBBLED = ("gpipe", "1f1b", "dapple", "zb-h1")   # staggered full-B drain
M, N = 8, 4
F = B = 1.0
AR = 0.3


# ---------------------------------------------------------------------------
# Plan structure: AR ops and their instruction-stream lowering.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched,V", [("1f1b", 1), ("zb-h1", 1),
                                     ("1f1b-interleaved", 2)])
def test_ar_ops_one_bucket_per_device_chunk_after_all_compute(sched, V):
    plan = SP.build_schedule(sched, M, N, V, grad_sync=True)
    assert plan.has_grad_sync
    base = SP.build_schedule(sched, M, N, V)
    assert not base.has_grad_sync
    for n, ops in enumerate(plan.device_ops):
        ars = [i for i, op in enumerate(ops) if op.kind == "AR"]
        assert len(ars) == V, (sched, n, ars)
        # in-order execution: every AR sits after ALL of the device's
        # compute, so the chunk's grad bucket is final when it syncs
        last_compute = max(i for i, op in enumerate(ops)
                           if op.kind != "AR")
        assert min(ars) > last_compute, (sched, n)
        # the non-AR op sequence is exactly the base builder's
        assert [op for op in ops if op.kind != "AR"] == \
            list(base.device_ops[n])


def test_add_grad_sync_idempotent_and_equals_builder_kwarg():
    via_kwarg = SP.build_schedule("1f1b", M, N, 1, grad_sync=True)
    via_add = SP.add_grad_sync(SP.build_schedule("1f1b", M, N, 1))
    assert via_kwarg.device_ops == via_add.device_ops
    again = SP.add_grad_sync(via_add)
    assert again.device_ops == via_add.device_ops


@pytest.mark.parametrize("sched", BUILDERS)
def test_lowering_gates_exactly_the_ar_slots(sched):
    plan = SP.build_schedule(sched, M, N, 1, grad_sync=True)
    instr = SP.lower_to_instructions(plan)
    lowered = SP.lower_to_ticks(plan)
    nT = len(lowered.kind[0])
    assert len(instr.arsync) == nT
    for t in range(nT):
        any_ar = any(lowered.kind[n][t] == SP.TICK_AR
                     for n in range(N))
        assert instr.arsync[t] == any_ar, (sched, t)
    # the drain readiness rule: stage N-1 finishes first and syncs
    # earliest, stage 0 last — AR slots ascend as the device index falls
    slot = {n: next(t for t in range(nT)
                    if lowered.kind[n][t] == SP.TICK_AR)
            for n in range(N)}
    assert all(slot[n] >= slot[n + 1] for n in range(N - 1)) or \
        sched in ("zb-h2", "zb-auto"), (sched, slot)


# ---------------------------------------------------------------------------
# Simulator pins: overlapped vs sync-at-end makespan.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched", BUILDERS)
def test_overlapped_makespan_never_worse_than_sync_at_end(sched):
    base = simulate(sched, M, N, F, B, 0.0)
    ov = simulate(sched, M, N, F, B, 0.0, ar=AR, grad_sync=True)
    sequential = base.makespan + N * AR
    assert ov.makespan <= sequential + 1e-12, sched


@pytest.mark.parametrize("sched", BUBBLED)
def test_overlapped_strictly_below_sequential_for_bubbled_builders(sched):
    """Uniform 2(data) x 4(stage) acceptance fixture: every builder
    whose drain staggers (the full-backward recrossing leaves device n
    idle n*B before the end) hides all but the last bucket."""
    base = simulate(sched, M, N, F, B, 0.0)
    ov = simulate(sched, M, N, F, B, 0.0, ar=AR, grad_sync=True)
    sequential = base.makespan + N * AR
    assert ov.makespan < sequential - 1e-12, sched
    # drain stagger >= total sync here, so only the LAST bucket (the
    # stage-0 device's, released at T itself) is exposed
    assert ov.makespan == pytest.approx(base.makespan + AR)


@pytest.mark.parametrize("sched", BUILDERS)
@pytest.mark.parametrize("comm", ["free", "latency", "blocking"])
def test_closed_form_matches_replay_under_every_comm_model(sched, comm):
    """The overlap-aware closed form (max_j (T_(j) + sum_{k>=j} ar_(k))
    over ascending drain ends) equals the discrete-event replay of the
    AR-op plan, for uniform and per-device ar vectors, under all three
    comm models (AR rides the data fabric, not the stage rings — the
    comm model moves T but not the sync overlap structure)."""
    ar_vec = tuple(0.1 * (n + 1) for n in range(N))
    sr = 0.05 if comm != "free" else 0.0
    base = simulate(sched, M, N, F, B, sr, comm=comm)
    ov = simulate(sched, M, N, F, B, sr, comm=comm, ar=ar_vec,
                  grad_sync=True)
    got = grad_sync_fifo(base.t_end, ar_vec)
    assert ov.makespan == pytest.approx(got), sched


def test_equality_iff_zero_stagger():
    """ov == seq exactly when every device drains at the same instant
    (no bubble left to hide the sync in); any stagger strictly wins."""
    flat = grad_sync_fifo((10.0, 10.0, 10.0, 10.0), (1.0,) * 4)
    assert flat == pytest.approx(10.0 + 4.0)          # == sequential
    staggered = grad_sync_fifo((10.0, 9.0, 8.0, 7.0), (1.0,) * 4)
    assert staggered < 14.0
    assert staggered == pytest.approx(11.0)           # T + last bucket


@pytest.mark.parametrize("sched", BUILDERS)
def test_eval_grad_sync_agrees_with_replay(sched):
    ev = eval_grad_sync(sched, M, N, F, B, AR)
    ov = simulate(sched, M, N, F, B, 0.0, ar=AR, grad_sync=True)
    base = simulate(sched, M, N, F, B, 0.0)
    assert ev.overlapped == pytest.approx(ov.makespan), sched
    assert ev.sequential == pytest.approx(base.makespan + N * AR)
    assert ev.exposed >= 0.0 and ev.hidden >= 0.0
    assert tuple(ev.t_ends) == tuple(base.t_end)


# ---------------------------------------------------------------------------
# Heterogeneous: the table_hetero skew.
# ---------------------------------------------------------------------------

def _skewed_costs():
    """The ``table_hetero`` fixture: 7 balanced layers on a
    fast/slow/fast/slow chain — granularity the partitioner cannot even
    out, so the drain stays genuinely staggered."""
    prof = NetworkProfile("balanced7", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e6,
                     bytes_act_out=1e9) for i in range(7)), unit="sample")
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0)
    slow = dataclasses.replace(fast, name="slow", peak_flops=50e12)
    cl = heterogeneous_cluster([fast, slow, fast, slow])
    r = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                candidate_Vs=())
    return r, r.plan.cost_vector()


def test_hetero_overlap_strictly_wins_and_exposes_one_bucket():
    """ISSUE acceptance: on the skewed ``table_hetero`` fixture the
    exposed sync cost drops to (near) zero — a single bucket's fabric
    time, everything else hidden in the staggered drain — and the
    closed form matches ``simulate_costs`` replaying the AR-op plan."""
    r, costs = _skewed_costs()
    name = SP.canonical_name(r.schedule)
    # replay the COST-SHAPED table when the pick is zb-auto (the one
    # the hetero eval ranks), not the uniform-cost table the bare name
    # would rebuild
    table = (SP.build_zb_auto(
        M, N, costs=(list(costs.F), list(costs.B), list(costs.W)))
        if name == "zb-auto" else name)
    # free comm: the async premise the hetero evals rank under (their
    # replay strips SR), so the replay and closed form see one drain
    base = simulate_costs(table, M, N, costs, comm="free")
    ar = 0.05 * base.makespan / N       # bubble comfortably covers it
    ev = eval_grad_sync_costs(name, M, N, costs, ar)
    ov = simulate_costs(table, M, N, costs, ar=ar, grad_sync=True,
                        comm="free")
    sequential = base.makespan + N * ar
    assert ov.makespan == pytest.approx(ev.overlapped)
    assert ov.makespan < sequential - 1e-9
    # mostly hidden even for the near-bubble-free winner
    assert ev.exposed / (N * ar) < 0.5
    for s in BUBBLED:
        evs = eval_grad_sync_costs(s, M, N, costs, ar)
        ovs = simulate_costs(s, M, N, costs, ar=ar, grad_sync=True,
                             comm="free")
        assert ovs.makespan == pytest.approx(evs.overlapped), s
        assert evs.overlapped < evs.sequential - 1e-9, s
        # near zero: the bubbled drains stagger more than the whole
        # sync, so only the LAST bucket (released at T) stays exposed
        assert evs.exposed <= ar * (1 + 1e-9), s


# ---------------------------------------------------------------------------
# Explorer: DP degree enters the ranking honestly.
# ---------------------------------------------------------------------------

def test_explorer_ranks_by_overlapped_makespan():
    """With ``dp_degree > 1`` the explorer adds only the EXPOSED sync
    to each candidate's time (carrying the eval), so the ranking sees
    the overlap the AR runtime actually achieves — not the sync-at-end
    penalty and not free gradients."""
    prof = NetworkProfile("uniform8", tuple(
        LayerProfile(name=f"l{i}", flops_fwd=1e12, bytes_weights=1e8,
                     bytes_act_out=1e9) for i in range(8)), unit="sample")
    fast = DeviceSpec("fast", 100e12, 1e12, 1e15, 1e15,
                      async_capable=True, efficiency=1.0,
                      data_bandwidth=5e14)
    cl = homogeneous_cluster(fast, 4)
    r1 = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                 candidate_Vs=(), dp_degree=1)
    r2 = explore(prof, cl, M, candidate_Ms=[M], consider_dp=False,
                 candidate_Vs=(), dp_degree=2)
    assert r1.grad_sync_eval is None
    ev = r2.grad_sync_eval
    assert ev is not None and ev.exposed >= 0.0
    # same compute plan, so the DP=2 time is the DP=1 time plus exactly
    # the exposed (not the sequential) sync
    assert r2.minibatch_time == pytest.approx(
        r1.minibatch_time + ev.exposed)
    assert ev.exposed < sum(ev.ars) - 1e-12   # some of it actually hid
