"""Dependency-free stand-in for the slice of the ``hypothesis`` API this
test suite uses (``given``/``settings``/``strategies``), so the tier-1
suite collects and runs in environments without hypothesis installed.

Semantics: ``@given(x=st.integers(0, 9))`` reruns the test body
``max_examples`` times with *seeded deterministic* samples (one fixed RNG
per test, keyed by the test name), so runs are reproducible.  ``settings``
mirrors hypothesis's decorator-stacking: it may wrap either the raw
function (below ``@given``) or the runner (above it).

This is intentionally NOT a property-testing engine — no shrinking, no
example database — just enough structure-aware random sweeping to keep the
suite's coverage when the real dependency is absent.  Install
``hypothesis`` (see requirements-dev.txt) to get the real thing.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    """Mimics ``hypothesis.strategies`` (imported as ``st``)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def given(**param_strategies):
    def decorate(fn):
        inner = fn
        # @settings below @given already wrapped fn; unwrap for the name
        name = getattr(fn, "__name__", "test")

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_hypo_max_examples",
                        getattr(inner, "_hypo_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(name.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng)
                         for k, s in param_strategies.items()}
                inner(*args, **kwargs, **drawn)

        runner._hypo_given = True
        # hide the drawn parameters from pytest's fixture resolution (the
        # real hypothesis exposes a parameterless wrapper the same way)
        sig = inspect.signature(fn)
        runner.__signature__ = sig.replace(parameters=[
            p for nm, p in sig.parameters.items()
            if nm not in param_strategies])
        runner.__dict__.pop("__wrapped__", None)
        return runner
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts (and mostly ignores) hypothesis settings; only
    ``max_examples`` matters to the shim."""
    def decorate(fn):
        fn._hypo_max_examples = max_examples
        return fn
    return decorate
