"""Per-architecture smoke tests: a REDUCED variant of each assigned
architecture runs one forward + one train step on CPU; output shapes and
finiteness asserted.  (Full configs are exercised by the dry-run only.)"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_config
from repro.configs.base import INPUT_SHAPES, LONG_CONTEXT_OK
from repro.models import model as M
from repro.optim import AdamW

ARCHS = all_arch_ids()


def make_batch(cfg, B=2, T=64, seed=1):
    kt, kl, kf = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = dict(tokens=jax.random.randint(kt, (B, T), 0, cfg.vocab),
                 labels=jax.random.randint(kl, (B, T), 0, cfg.vocab))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(kf, (B, 32, cfg.d_model))
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(kf, (B, T, cfg.d_model))
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_routed <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    h, aux, _ = M.forward(cfg, params, batch)
    assert h.shape == (2, 64, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_one_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)) ** 0.5
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    new_params, state = opt.update(params, grads, state)
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0
    loss2 = M.loss_fn(cfg, new_params, batch)
    assert bool(jnp.isfinite(loss2))


def test_full_configs_match_assignment():
    spec = {
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
        assert cfg.stages * cfg.tensor == 16, arch


def test_family_features_present():
    assert get_config("mamba2-2.7b").ssm.d_state == 128
    assert get_config("hymba-1.5b").ssm.d_state == 16
    assert get_config("deepseek-v3-671b").moe.n_routed == 256
    assert get_config("deepseek-v3-671b").moe.top_k == 8
    assert get_config("deepseek-v2-lite-16b").moe.top_k == 6
    assert get_config("deepseek-v2-lite-16b").mla.kv_lora_rank == 512
    assert get_config("gemma3-1b").global_every == 6
    assert get_config("gemma3-1b").window == 512
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("qwen2-vl-7b").mrope_sections == (16, 24, 24)
    assert get_config("whisper-base").n_enc_layers == 3
    assert get_config("llama3.2-1b").rope_theta == 500_000.0


def test_long_context_policy():
    assert LONG_CONTEXT_OK == {"mamba2-2.7b", "hymba-1.5b", "gemma3-1b"}
    assert INPUT_SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", ["minicpm3-4b", "llama3.2-1b",
                                  "deepseek-v2-lite-16b"])
def test_param_counts_roughly_match_model_size(arch):
    """Analytic parameter counts land near the advertised model size."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {"minicpm3-4b": 4.0e9, "llama3.2-1b": 1.24e9,
                "deepseek-v2-lite-16b": 15.7e9}[arch]
    assert 0.6 * expected < n < 1.5 * expected, (arch, n)
