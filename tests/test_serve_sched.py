"""Unit tests for the continuous-batching serving layer: scheduler
admission/roles, mixed op tables, the slot-memory budget, the kv-scoped
cache-offset surgery, and the donation contracts the serving runtime
relies on (restack handoff + slot reset free their inputs)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import serve_sched as SS
from repro.models import model as M
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST


# ---------------------------------------------------------------------------
# Scheduler: admission, per-step roles, retirement.
# ---------------------------------------------------------------------------

def _req(rid, plen, max_new=2, arrival=0):
    return SS.Request(rid=rid, prompt=list(range(1, plen + 1)),
                      max_new=max_new, arrival=arrival)


def test_admission_lowest_free_slot_fifo():
    s = SS.ServeScheduler(n_slots=2, chunk=4)
    a, b, c = _req(0, 3), _req(1, 3), _req(2, 3)
    assert s.admit(a) and a.slot == 0
    assert s.admit(b) and b.slot == 1
    assert not s.admit(c)                 # full
    s.slots[0] = None                     # retire a
    assert s.admit(c) and c.slot == 0     # lowest free slot reused


def test_plan_step_mixed_roles_and_chunking():
    s = SS.ServeScheduler(n_slots=3, chunk=4)
    pre = _req(0, 10)                     # needs 4 + 4 + 2 bites
    dec = _req(1, 2)
    s.admit(pre), s.admit(dec)
    dec.pos = 2                           # prompt done -> decoding
    dec.generated = [77]
    sp = s.plan_step()
    assert [w.kind for w in sp.work] == [SS.PREFILL, SS.DECODE, SS.IDLE]
    assert sp.n_valid.tolist() == [4, 1, 0]
    assert sp.tokens[0, :4].tolist() == pre.prompt[:4]
    assert sp.tokens[1, 0] == 77          # decode feeds last sampled token
    assert sp.busy == 2


def test_observe_prefill_to_decode_handoff_and_retire():
    """Mid-prompt chunks discard their logits; the chunk that completes
    the prompt emits the FIRST new token (the V>1 handoff bug class);
    retirement frees the slot."""
    s = SS.ServeScheduler(n_slots=1, chunk=4)
    r = _req(0, 6, max_new=2)
    s.admit(r)
    sp = s.plan_step()                    # bite 1: 4 prompt tokens
    s.observe(sp, np.array([11]), t=0)
    assert r.generated == [] and r.pos == 4
    sp = s.plan_step()                    # bite 2 completes the prompt
    assert sp.n_valid.tolist() == [2]
    s.observe(sp, np.array([22]), t=1)
    assert r.generated == [22] and r.t_first == 1
    sp = s.plan_step()                    # decode tick -> max_new reached
    assert sp.work[0].kind == SS.DECODE
    fin = s.observe(sp, np.array([33]), t=2)
    assert fin == [r] and r.generated == [22, 33] and r.t_done == 2
    assert s.slots[0] is None and s.retired == [r]


def test_engine_run_returns_only_newly_retired():
    """Repeated ``run`` calls on one engine (the sequential baseline)
    must not double-count earlier retirements."""
    cfg = get_config("llama3.2-1b").reduced()
    step = SS.make_local_serve_step(cfg)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, 2, max_len=16)
    eng = SS.ContinuousEngine(cfg, step, params, cache, 2, 4)
    first = eng.run([_req(0, 3, max_new=1)])
    second = eng.run([_req(1, 3, max_new=1)])
    assert [r.rid for r in first] == [0]
    assert [r.rid for r in second] == [1]
    assert [r.rid for r in eng.sched.retired] == [0, 1]


# ---------------------------------------------------------------------------
# Mixed op tables through the schedplan IR.
# ---------------------------------------------------------------------------

def test_mixed_op_table_roles_follow_microbatches():
    work = [SS.SlotWork(0, SS.PREFILL, 4, 0), SS.SlotWork(1, SS.DECODE, 1, 1),
            SS.SlotWork(2, SS.DECODE, 1, 2), SS.SlotWork(3, SS.IDLE, 0)]
    plan, roles = SS.mixed_op_table(work, M=2, N=2)
    assert roles == {0: (SS.PREFILL, SS.DECODE), 1: (SS.DECODE, SS.IDLE)}
    # micro-batch 0 is a genuinely mixed prefill+decode bundle
    assert len(set(roles[0])) > 1
    txt = SS.format_mixed_table(plan, roles)
    assert "F0[PD]" in txt and "F1[D-]" in txt
    # every micro-batch's F op appears on every device exactly once
    for dev, ops in enumerate(plan.device_ops):
        fs = [op.m for op in ops if op.kind == "F"]
        assert sorted(fs) == [0, 1], (dev, fs)


def test_mixed_op_table_interleaved_plan():
    work = [SS.SlotWork(0, SS.DECODE, 1, 0), SS.SlotWork(1, SS.DECODE, 1, 1)]
    plan, roles = SS.mixed_op_table(work, M=2, N=2, V=2)
    assert plan.V == 2
    txt = SS.format_mixed_table(plan, roles)
    assert "F0.0[D]" in txt and "F0.1[D]" in txt


# ---------------------------------------------------------------------------
# Memory gating: slots <-> cache bytes (explorer analogue).
# ---------------------------------------------------------------------------

def test_kv_bytes_per_slot_matches_cache():
    for arch in ("llama3.2-1b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch).reduced()
        got = SS.kv_bytes_per_slot(cfg, max_len=32)
        kv = M.init_cache(cfg, 1, max_len=32)["kv"]
        real = sum(a.nbytes for k, a in kv.items() if k != "len")
        assert got == real, (arch, got, real)


def test_serve_slot_budget_floors_and_gates():
    cfg = get_config("llama3.2-1b").reduced()
    per = SS.kv_bytes_per_slot(cfg, 32) / cfg.n_layers \
        * -(-cfg.n_layers // 2)  # 2-stage per-slot bytes
    assert SS.serve_slot_budget(cfg, 32, per * 0.5, n_stages=2) == 0
    assert SS.serve_slot_budget(cfg, 32, per * 7.5, n_stages=2,
                                microbatches=4) == 4  # floored from 7
    assert SS.serve_slot_budget(cfg, 32, per * 7.5, n_stages=2,
                                weight_bytes=per * 4,
                                microbatches=1) == 3  # weights charged
    big = SS.serve_slot_budget(cfg, 32, per * 100, n_stages=2)
    assert big > SS.serve_slot_budget(cfg, 32, per * 10, n_stages=2)


# ---------------------------------------------------------------------------
# kv-scoped offset surgery: _advance_len/_restore_len touch ONLY kv lens.
# ---------------------------------------------------------------------------

def _kv_len_paths(cache):
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    return {jax.tree_util.keystr(p) for p, _ in flat
            if RT._is_kv_len(p)}


@pytest.mark.parametrize("arch,pinned", [
    ("llama3.2-1b", {"['kv']['len']"}),
    ("deepseek-v2-lite-16b", {"['kv']['len']"}),
    ("hymba-1.5b", {"['kv']['len']"}),        # ssm subtree must NOT match
    ("whisper-base", {"['kv']['len']"}),      # xk/xv must NOT match
])
def test_advance_len_scope_pinned(arch, pinned):
    cfg = get_config(arch).reduced()
    cache = M.init_cache(cfg, batch=3, max_len=8, enc_len=4)
    assert _kv_len_paths(cache) == pinned
    adv = RT._advance_len(cache, jnp.array([1, 2, 3]))
    flat0 = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat1 = {jax.tree_util.keystr(p): a
             for p, a in jax.tree_util.tree_flatten_with_path(adv)[0]}
    for p, a in flat0:
        key = jax.tree_util.keystr(p)
        if key in pinned:
            assert (flat1[key] == a + jnp.array([1, 2, 3])).all()
        else:
            assert (flat1[key] == a).all(), key  # untouched bit-for-bit
    back = RT._restore_len(adv, cache)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool((x == y).all()), back, cache))


def test_advance_len_scalar_broadcasts_over_slots():
    cfg = get_config("llama3.2-1b").reduced()
    cache = M.init_cache(cfg, batch=2, max_len=8)
    adv = RT._advance_len(cache, 5)
    assert (adv["kv"]["len"] == 5).all()
    assert adv["kv"]["len"].shape == (cfg.n_layers, 2)


# ---------------------------------------------------------------------------
# Donation pins: the serving handoffs must FREE their inputs (the old
# eager paths held params+cache twice).
# ---------------------------------------------------------------------------

def test_reset_slot_offsets_donates_cache():
    cfg = get_config("llama3.2-1b").reduced()
    cache = M.init_cache(cfg, batch=4, max_len=8)
    cache = RT._advance_len(cache, 3)
    old_leaves = jax.tree.leaves(cache)
    out = SS.reset_slot_offsets(cache, np.array([True, False, True, False]))
    assert out["kv"]["len"][:, 0].tolist() == [0] * cfg.n_layers
    assert out["kv"]["len"][:, 1].tolist() == [3] * cfg.n_layers
    assert all(l.is_deleted() for l in old_leaves)


def test_restack_handoff_frees_prefill_buffers():
    """The V>1 prefill->decode restack (serve.py) runs as one donated
    jitted call and must not leave the prefill-layout copies resident:
    leaves whose layout survives (embed/head/final_norm pass-throughs)
    are aliased in place and deleted by the donation; the chunk-stacked
    ``layers``/cache leaves change shape (XLA cannot alias them — the
    'donated buffers were not usable' warning) and must be freed the
    moment the caller drops its reference, which serve.py does with
    ``del params_p`` right after the handoff."""
    import weakref
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(
        n_layers=4, d_model=64), stages=2, virtual=2)
    plan_p = ST.plan_stages(cfg)                   # [S, V, Lc, ...]
    plan = ST.plan_stages(cfg, virtual=1)          # [S, Lps, ...]
    params_p = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan_p)
    cache_p = RT.init_pipeline_cache(cfg, plan_p, 2, 8)

    def _restack(p, c):
        p2 = ST.restack_params(p, plan_p, plan, cfg.n_layers)
        c2 = jax.tree.map(
            lambda a: ST.restack_layers(a, plan_p, plan, cfg.n_layers), c)
        return p2, c2

    fn = jax.jit(_restack, donate_argnums=(0, 1))
    passthrough = [l for k, l in params_p.items() if k != "layers"]
    refolded = [weakref.ref(l) for l in
                jax.tree.leaves(params_p["layers"])
                + jax.tree.leaves(cache_p)]
    assert refolded
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        params, cache = fn(params_p, cache_p)
    jax.block_until_ready(jax.tree.leaves(params)[0])
    assert all(l.is_deleted() for l in passthrough)   # aliased in place
    del params_p, cache_p                             # what serve.py does
    assert all(r() is None for r in refolded)         # ...frees the rest
    # and the restack itself is correct: layer order survives the re-fold
    ref = ST.restack_params(
        ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan_p),
        plan_p, plan, cfg.n_layers)
    assert jax.tree.all(jax.tree.map(
        lambda x, y: bool((x == y).all()), params, ref))
