"""Drift monitor + deadline-bounded replanning: no trigger under noise,
trigger under skew, budget respected, and a simulator-pinned never-worse
guarantee for the replanned configuration."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.autoplan import auto_plan, replan, _derated, _stage_device
from repro.core.explorer import explore
from repro.core.hardware import TPU_V5E, heterogeneous_cluster
from repro.core.profiler import (DriftMonitor, measure_stage_times,
                                 planned_stage_costs, profile_arch,
                                 stage_layer_kinds)
from repro.core.schedplan import canonical_name
from repro.core.simulator import simulate_costs
from repro.pipeline import stage as ST


# ---------------------------------------------------------------------------
# DriftMonitor
# ---------------------------------------------------------------------------

def test_no_trigger_under_noise():
    """Small measurement noise around the planned shares must never
    trip the monitor, no matter how many samples arrive."""
    import random
    rnd = random.Random(0)
    mon = DriftMonitor(planned=(1.0, 1.2, 0.9, 1.1), threshold=0.25)
    for _ in range(50):
        noisy = [p * (1 + rnd.uniform(-0.05, 0.05)) for p in mon.planned]
        mon.update(noisy)
        assert not mon.should_replan(), (mon.drift(), mon.n_samples)
    assert mon.drift() < 0.25


def test_trigger_under_skew():
    mon = DriftMonitor(planned=(1.0, 1.0, 1.0, 1.0), threshold=0.25,
                       min_samples=3)
    for _ in range(6):
        mon.update([3.0, 1.0, 1.0, 1.0])
    assert mon.should_replan()
    slow = mon.slowdown()
    assert max(range(4), key=lambda i: slow[i]) == 0
    assert slow[0] > 1.0 > slow[1]


def test_min_samples_gates_trigger():
    """One wild sample is not drift — the EMA must absorb min_samples
    updates before the trigger can arm."""
    mon = DriftMonitor(planned=(1.0, 1.0), threshold=0.25, min_samples=3)
    mon.update([10.0, 1.0])
    assert mon.drift() > 0.25 and not mon.should_replan()
    mon.update([10.0, 1.0])
    assert not mon.should_replan()
    mon.update([10.0, 1.0])
    assert mon.should_replan()


def test_scale_invariance():
    """A uniformly slower host (every stage x1000) is NOT drift — only
    the ratio between stages matters."""
    mon = DriftMonitor(planned=(1.0, 2.0, 1.0), min_samples=1)
    for _ in range(5):
        mon.update([1000.0, 2000.0, 1000.0])
    assert mon.drift() == pytest.approx(0.0, abs=1e-12)
    assert not mon.should_replan()
    assert mon.slowdown() == pytest.approx((1.0, 1.0, 1.0))


def test_update_validates_input():
    mon = DriftMonitor(planned=(1.0, 1.0))
    with pytest.raises(ValueError):
        mon.update([1.0])                 # wrong length
    with pytest.raises(ValueError):
        mon.update([1.0, 0.0])            # non-positive
    with pytest.raises(ValueError):
        DriftMonitor(planned=(1.0, -1.0))


def test_planned_stage_costs_follow_layer_ownership():
    """The planned vector charges each stage its owned real layers —
    uneven padding shows up as a lighter last stage."""
    cfg = get_config("llama3.2-1b").reduced(n_layers=6, d_model=64)
    plan = ST.plan_stages(cfg, n_stages=4, virtual=1)   # Lps=2, 2 padded
    kinds = stage_layer_kinds(cfg, plan)
    assert [len(k) for k in kinds] == [2, 2, 2, 0]
    costs = planned_stage_costs(cfg, plan, seq=64)
    assert costs[0] == costs[1] == costs[2] > costs[3] > 0


def test_measure_stage_times_shape_and_weighting():
    """Live timings: one proxy per kind, charged per owned layer —
    a stage owning 2 layers reads ~2x a stage owning 1 (exactly 2x,
    since both charge the same per-kind median)."""
    cfg = get_config("llama3.2-1b").reduced(n_layers=6, d_model=64)
    plan = ST.plan_stages(cfg, n_stages=4, virtual=1)
    t = measure_stage_times(cfg, plan, seq=16, iters=1)
    if t is None:
        pytest.skip("proxy timing unavailable")
    assert len(t) == 4
    assert t[0] == t[1] == t[2] > 0
    assert t[3] == 0.0                     # owns only padded slots


# ---------------------------------------------------------------------------
# replan: budget + never-worse
# ---------------------------------------------------------------------------

def _cfg4():
    # n_layers == stages => the explorer cannot interleave (V pinned 1),
    # so simulate_costs (V == 1 only) can replay every candidate
    return get_config("llama3.2-1b").reduced(n_layers=4, d_model=64)


def _incumbent(cfg):
    return auto_plan(cfg, global_batch=32, seq_len=128, model_axis=4,
                     data_axis=1, devices=[TPU_V5E] * 4)


def test_zero_budget_returns_incumbent_object():
    cfg = _cfg4()
    inc = _incumbent(cfg)
    assert replan(cfg, inc, budget_s=0.0, global_batch=32,
                  seq_len=128) is inc


def test_budget_stops_search_between_candidates():
    """With a fake clock that expires right after the first candidate,
    only the incumbent's factorisation is evaluated — the result still
    carries the incumbent's (stages, tensor)."""
    cfg = _cfg4()
    inc = _incumbent(cfg)
    calls = []

    def clock():
        calls.append(None)
        return 0.0 if len(calls) == 1 else 1e9

    out = replan(cfg, inc, budget_s=1.0, global_batch=32, seq_len=128,
                 slowdown=[2.0, 1.0, 1.0, 1.0], clock=clock)
    assert (out.stages, out.tensor) == (inc.stages, inc.tensor)
    # deadline consulted at least once after the first evaluation
    assert len(calls) >= 2


def test_replan_no_skew_keeps_incumbent():
    """Same fleet, same costs: the re-search lands on the incumbent's
    own configuration and returns the incumbent OBJECT (callers use
    identity to skip a no-op restart)."""
    cfg = _cfg4()
    inc = _incumbent(cfg)
    out = replan(cfg, inc, budget_s=60.0, global_batch=32, seq_len=128,
                 slowdown=[1.0, 1.0, 1.0, 1.0])
    assert out is inc


def test_replan_never_worse_simulator_pinned():
    """Acceptance pin: under an injected 3x skew of stage 0, the
    replanned configuration's scheduled makespan on the SKEWED cluster —
    replayed by the simulator, not the explorer's own score — must be
    <= the incumbent configuration's makespan on that same cluster."""
    cfg = _cfg4()
    inc = _incumbent(cfg)
    sl = [3.0, 1.0, 1.0, 1.0]
    new = replan(cfg, inc, budget_s=60.0, global_batch=32, seq_len=128,
                 slowdown=sl)
    assert new.stages == inc.stages and new.virtual == 1

    prof = profile_arch(cfg, seq=128)
    cluster = heterogeneous_cluster(
        [_stage_device(_derated(TPU_V5E, f), inc.tensor) for f in sl])

    def eval_config(plan_cfg):
        r = explore(prof, cluster, 32 * 128,
                    candidate_Ms=[plan_cfg.n_microbatches],
                    consider_dp=False, dp_degree=1)
        assert r.plan is not None
        costs = r.plan.cost_vector()
        sim = simulate_costs(canonical_name(plan_cfg.schedule),
                             plan_cfg.n_microbatches, plan_cfg.stages,
                             costs)
        return sim.makespan

    assert eval_config(new) <= eval_config(inc) + 1e-12
    # and the explorer-side score agrees with the ordering
    assert new.predicted_step_time <= inc.predicted_step_time * 10


def test_replan_slowdown_length_validated():
    cfg = _cfg4()
    inc = _incumbent(cfg)
    with pytest.raises(ValueError):
        replan(cfg, inc, budget_s=1.0, global_batch=32, seq_len=128,
               slowdown=[2.0, 1.0])
