"""Plan-to-plan checkpoint resharding: randomized round-trip sweep over
(N, V) layouts, file-to-file relayout, and guard rails."""
import itertools
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import (CheckpointMismatch, checkpoint_meta,
                              layout_dict, plan_from_layout,
                              reshard_checkpoint, reshard_tree,
                              restore_checkpoint, save_checkpoint)
from repro.pipeline import stage as ST
from repro.pipeline.stage import StagePlan


def _plan(stages, virtual, n_layers):
    import math
    lc = math.ceil(n_layers / (stages * virtual))
    return StagePlan(n_stages=stages, tensor=1, layers_per_stage=lc,
                     n_layers_padded=stages * virtual * lc, virtual=virtual)


def _layer_tree(rng, plan, n_layers, dims=((3, 2), (4,))):
    """A params-like tree with distinct per-layer values, stacked under
    ``plan`` (padded slots repeat the last real layer, as the runtime
    init/reshard both do)."""
    global_tree = {f"w{i}": rng.standard_normal(
        (n_layers,) + d).astype(np.float32) for i, d in enumerate(dims)}
    pad = plan.n_layers_padded - n_layers
    stacked = {}
    for k, a in global_tree.items():
        if pad:
            a = np.concatenate([a, np.repeat(a[-1:], pad, 0)], 0)
        stacked[k] = np.asarray(ST._stack_chunks(jax.numpy.asarray(a), plan))
    return global_tree, stacked


LAYOUTS = [(1, 1), (2, 1), (2, 2), (4, 1), (4, 2), (3, 1), (8, 1)]


@pytest.mark.parametrize("n_layers", [8, 12, 7])
def test_reshard_roundtrip_sweep(n_layers):
    """Every (N, V) -> (N', V') relayout preserves the real layers
    bit-for-bit, for contiguous and interleaved plans, even and uneven
    layer counts."""
    rng = np.random.default_rng(0)
    for (sa, va), (sb, vb) in itertools.product(LAYOUTS, LAYOUTS):
        pa, pb = _plan(sa, va, n_layers), _plan(sb, vb, n_layers)
        glob, stacked = _layer_tree(rng, pa, n_layers)
        tree = dict(layers=stacked, embed=rng.standard_normal(
            (5, 3)).astype(np.float32))
        out = reshard_tree(tree, pa, pb, n_layers)
        for k, g in glob.items():
            back = np.asarray(ST.unstack_chunks(
                jax.numpy.asarray(out["layers"][k]), pb))[:n_layers]
            np.testing.assert_array_equal(back, g), (sa, va, sb, vb, k)
        # non-layer leaves pass through untouched
        np.testing.assert_array_equal(np.asarray(out["embed"]),
                                      tree["embed"])


def test_reshard_tree_covers_opt_moment_mirrors():
    """Optimizer moments mirror the params structure — their ``layers``
    subtrees must be restacked exactly like the params'."""
    rng = np.random.default_rng(1)
    n_layers = 8
    pa, pb = _plan(4, 1, n_layers), _plan(2, 2, n_layers)
    glob, stacked = _layer_tree(rng, pa, n_layers)
    globm, stackedm = _layer_tree(rng, pa, n_layers)
    state = dict(params=dict(layers=stacked),
                 opt=dict(m=dict(layers=stackedm),
                          step=np.int32(5)))
    out = reshard_tree(state, pa, pb, n_layers)
    for k, g in globm.items():
        back = np.asarray(ST.unstack_chunks(
            jax.numpy.asarray(out["opt"]["m"]["layers"][k]), pb))[:n_layers]
        np.testing.assert_array_equal(back, g)
    assert out["opt"]["step"] == 5


def test_reshard_rejects_wrong_source_layout():
    rng = np.random.default_rng(2)
    n_layers = 8
    pa, pb = _plan(4, 1, n_layers), _plan(2, 2, n_layers)
    _, stacked = _layer_tree(rng, pa, n_layers)
    wrong_from = _plan(8, 1, n_layers)     # claims [8, 1, ...] stacking
    with pytest.raises(CheckpointMismatch):
        reshard_tree(dict(layers=stacked), wrong_from, pb, n_layers)


def test_reshard_checkpoint_file_to_file(tmp_path):
    """Host-side relayout of a saved {params, opt} checkpoint: values,
    dtypes, step, and non-layer leaves preserved; meta layout updated;
    restore on the target plan succeeds with no device mesh."""
    rng = np.random.default_rng(3)
    n_layers = 8
    pa, pb = _plan(4, 1, n_layers), _plan(2, 2, n_layers)
    glob, stacked = _layer_tree(rng, pa, n_layers)
    state = dict(params=dict(layers=stacked,
                             embed=rng.standard_normal((5, 3)).astype(
                                 np.float32)),
                 opt=dict(m=dict(layers=stacked),
                          step=np.int32(9)))
    src, dst = str(tmp_path / "a"), str(tmp_path / "b")
    save_checkpoint(src, state, step=9,
                    extra=dict(layout=layout_dict(pa, n_layers)))
    new_layout = reshard_checkpoint(src, dst, pb)
    assert new_layout["stages"] == 2 and new_layout["virtual"] == 2
    meta = checkpoint_meta(dst)
    assert meta["step"] == 9
    assert meta["extra"]["layout"] == new_layout
    assert plan_from_layout(meta["extra"]["layout"]) == pb

    like = dict(params=dict(
        layers={k: np.zeros((2, 2, 2) + v.shape[2:], np.float32)
                for k, v in stacked.items()},
        embed=np.zeros((5, 3), np.float32)),
        opt=dict(m=dict(layers={k: np.zeros((2, 2, 2) + v.shape[2:],
                                            np.float32)
                                for k, v in stacked.items()}),
                 step=np.int32(0)))
    r = restore_checkpoint(dst, like)
    for k, g in glob.items():
        back = np.asarray(ST.unstack_chunks(
            jax.numpy.asarray(r["params"]["layers"][k]), pb))[:n_layers]
        np.testing.assert_array_equal(back, g)
    np.testing.assert_array_equal(np.asarray(r["params"]["embed"]),
                                  state["params"]["embed"])
    assert int(r["opt"]["step"]) == 9


def test_reshard_checkpoint_rejects_tensor_change(tmp_path):
    rng = np.random.default_rng(4)
    n_layers = 4
    pa = _plan(2, 1, n_layers)
    _, stacked = _layer_tree(rng, pa, n_layers)
    src = str(tmp_path / "a")
    save_checkpoint(src, dict(layers=stacked),
                    extra=dict(layout=layout_dict(pa, n_layers)))
    pb = StagePlan(n_stages=2, tensor=2, layers_per_stage=2,
                   n_layers_padded=4, virtual=1)
    with pytest.raises(CheckpointMismatch) as ei:
        reshard_checkpoint(src, str(tmp_path / "b"), pb)
    assert "tensor" in str(ei.value)


def test_reshard_checkpoint_needs_layout_or_plans(tmp_path):
    rng = np.random.default_rng(5)
    pa = _plan(2, 1, 4)
    _, stacked = _layer_tree(rng, pa, 4)
    src = str(tmp_path / "a")
    save_checkpoint(src, dict(layers=stacked))      # no layout recorded
    with pytest.raises(CheckpointMismatch) as ei:
        reshard_checkpoint(src, str(tmp_path / "b"), _plan(4, 1, 4))
    assert "layout" in str(ei.value)
    # explicit plans work without recorded layout
    reshard_checkpoint(src, str(tmp_path / "b"), _plan(4, 1, 4),
                       plan_from=pa, n_layers=4)


def test_target_too_small_rejected():
    rng = np.random.default_rng(6)
    n_layers = 8
    pa = _plan(4, 1, n_layers)
    _, stacked = _layer_tree(rng, pa, n_layers)
    too_small = StagePlan(n_stages=2, tensor=1, layers_per_stage=2,
                          n_layers_padded=4, virtual=1)
    with pytest.raises(CheckpointMismatch):
        reshard_tree(dict(layers=stacked), pa, too_small, n_layers)
