"""3D balanced partitioning: per-stage (dp, tp) search, TP-aware costs,
grouped AR release, and the 2BW sync-free steady state.

Pins the tentpole claims analytically (the runtime side is pinned by the
tp_equivalence / two_bw / ar_groups harness modes in
tests/test_pipeline_multidevice.py):

* hardware: per-axis bandwidths validate at construction; the
  link_bandwidth fallback is explicit (None), never a silent 0.0.
* profiler/partition: stage costs shard 1/tp with the Megatron
  collective priced on the tensor axis; boundary reshard SR; memory
  shards across both axes.
* explorer: the 3D space contains the 1D incumbent (structurally never
  worse) and on a skewed profile strictly beats the best pipeline-only
  plan at the same device count, simulator-pinned; candidate ranking is
  differentially consistent with the replay evaluator.
* schedules: grouped AR release is monotone (exposed sync non-increasing
  in groups, makespan untouched); 2BW exposed sync is zero whenever the
  fabric drains within one step.
"""
import random

import pytest

from repro.core.explorer import (PLAN3D_SCHEDULES, explore3d)
from repro.core.hardware import (TPU_V5E, DeviceSpec, FleetSpec,
                                 fused_device, homogeneous_fleet)
from repro.core.partition import plan_costs_3d, reshard_sr, stage_memory_3d
from repro.core.profiler import (LayerProfile, NetworkProfile,
                                 tp_collective_time)
from repro.core.schedules import (eval_grad_sync, eval_grad_sync_2bw,
                                  eval_grad_sync_costs)
from repro.core.simulator import simulate_costs


# ---------------------------------------------------------------------------
# hardware: explicit-axis-bandwidth validation (bugfix satellite)
# ---------------------------------------------------------------------------

def _dev(**kw):
    base = dict(name="d", peak_flops=1e12, hbm_bandwidth=1e11,
                memory_capacity=1e10, link_bandwidth=1e9)
    base.update(kw)
    return DeviceSpec(**base)


def test_explicit_zero_axis_bandwidth_rejected():
    """The old 0.0 default silently fell back to link_bandwidth, letting
    3D cost models price TP collectives at the inter-host rate; an
    explicit zero is now a construction error."""
    for axis in ("data", "stage", "tensor"):
        with pytest.raises(ValueError, match=f"{axis}_bandwidth"):
            _dev(**{f"{axis}_bandwidth": 0.0})
        with pytest.raises(ValueError, match=f"{axis}_bandwidth"):
            _dev(**{f"{axis}_bandwidth": -1.0})


def test_unset_axis_bandwidth_inherits_link_explicitly():
    d = _dev(tensor_bandwidth=5e9)
    assert d.axis_bandwidth("tensor") == 5e9
    assert d.axis_bandwidth("data") == d.link_bandwidth
    assert d.axis_bandwidth("stage") == d.link_bandwidth
    with pytest.raises(ValueError, match="unknown mesh axis"):
        d.axis_bandwidth("pod")


def test_nonpositive_link_bandwidth_rejected():
    with pytest.raises(ValueError, match="link_bandwidth"):
        _dev(link_bandwidth=0.0)


def test_catalogue_devices_have_explicit_axis_bandwidths():
    assert TPU_V5E.data_bandwidth and TPU_V5E.data_bandwidth > 0
    assert TPU_V5E.tensor_bandwidth and TPU_V5E.tensor_bandwidth > 0


# ---------------------------------------------------------------------------
# fleets and fused stage devices
# ---------------------------------------------------------------------------

def test_fused_device_scales_chip_resources():
    f = fused_device(TPU_V5E, 4)
    assert f.peak_flops == 4 * TPU_V5E.peak_flops
    assert f.hbm_bandwidth == 4 * TPU_V5E.hbm_bandwidth
    assert f.memory_capacity == 4 * TPU_V5E.memory_capacity
    assert f.name == f"{TPU_V5E.name}x4"
    assert fused_device(TPU_V5E, 1) is TPU_V5E
    with pytest.raises(ValueError):
        fused_device(TPU_V5E, 0)


def test_fleet_chain_carves_pool():
    fleet = homogeneous_fleet(TPU_V5E, 8)
    assert fleet.n_devices == 8 and fleet.homogeneous
    chain = fleet.chain([2, 4, 2])
    assert len(chain.devices) == 3
    assert [d.peak_flops for d in chain.devices] == \
        [2 * TPU_V5E.peak_flops, 4 * TPU_V5E.peak_flops,
         2 * TPU_V5E.peak_flops]
    with pytest.raises(ValueError):
        fleet.chain([4, 4, 4])      # over the 8-chip budget


# ---------------------------------------------------------------------------
# TP-aware stage costs, reshard SR, memory
# ---------------------------------------------------------------------------

def _skewed_profile():
    """Seven 1-GFLOP layers plus one 8x fat layer that depth alone
    cannot split — the stage that wants to buy width."""
    lays = []
    for i in range(8):
        fat = (i == 3)
        lays.append(LayerProfile(
            name=f"l{i}", flops_fwd=8e9 if fat else 1e9,
            bytes_weights=8e6 if fat else 1e6, bytes_act_out=1e4))
    return NetworkProfile(name="skewed", layers=tuple(lays), unit="sample")


def test_plan_costs_3d_width_annotation_and_tp_scaling():
    prof = _skewed_profile()
    bounds = [(0, 4), (4, 8)]
    c1 = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 1), (1, 1)])
    c2 = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 2), (1, 2)])
    assert c1.width == (1, 1) and c2.width == (2, 2)
    assert c1.widths == (1, 1) and c2.widths == (2, 2)
    assert c1.uniform_width and c2.uniform_width
    assert c2.devices_used() == 4
    # tp=2 shards the GEMMs: strictly faster per stage on this
    # compute-bound profile even after paying the collectives
    assert all(b < a for a, b in zip(c1.F, c2.F))
    assert all(b < a for a, b in zip(c1.B, c2.B))
    assert all(b < a for a, b in zip(c1.W, c2.W))
    # ... but not a free 2x: the collective cost is charged
    coll = tp_collective_time(prof.layers[0], TPU_V5E, 32, 2, 2)
    assert coll > 0.0
    assert c2.F[0] > c1.F[0] / 2


def test_plan_costs_3d_dp_divides_units():
    prof = _skewed_profile()
    bounds = [(0, 4), (4, 8)]
    c1 = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 1), (1, 1)])
    c2 = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(2, 1), (2, 1)])
    # dp=2 halves each replica's micro-batch share
    assert all(abs(b - a / 2) / a < 0.51 for a, b in zip(c1.F, c2.F))
    assert all(b < a for a, b in zip(c1.F, c2.F))


def test_reshard_sr_boundary_terms():
    bw = 1e9
    assert reshard_sr(0.0, (1, 1), (1, 2), bw) == 0.0
    same = reshard_sr(1e6, (1, 2), (1, 2), bw)
    assert same == pytest.approx(1e6 / (2 * bw))
    differ = reshard_sr(1e6, (1, 2), (1, 4), bw)
    # min(tp) slice transfer plus one extra full-activation pass
    assert differ == pytest.approx(1e6 / (2 * bw) + 1e6 / bw)
    assert differ > same
    # (dp, tp) mismatch with equal tp still pays the reshard pass
    dp_mismatch = reshard_sr(1e6, (2, 2), (1, 2), bw)
    assert dp_mismatch == pytest.approx(1e6 / (2 * bw) + 1e6 / bw)


def test_plan_costs_3d_charges_boundary_reshard():
    prof = _skewed_profile()
    bounds = [(0, 4), (4, 8)]
    uniform = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 2), (1, 2)])
    ragged = plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 2), (1, 4)])
    assert ragged.SR[0] > uniform.SR[0] > 0.0


def test_stage_memory_3d_shards_both_axes():
    prof = _skewed_profile()
    bounds = [(0, 4), (4, 8)]
    m11 = stage_memory_3d(prof, bounds, [(1, 1), (1, 1)], 32)
    m12 = stage_memory_3d(prof, bounds, [(1, 2), (1, 2)], 32)
    m22 = stage_memory_3d(prof, bounds, [(2, 2), (2, 2)], 32)
    assert all(b < a for a, b in zip(m11, m12))   # tp shards weights+acts
    assert all(c < b for b, c in zip(m12, m22))   # dp shards activations
    with pytest.raises(ValueError):
        plan_costs_3d(prof, TPU_V5E, bounds, 32, [(1, 2)])
    with pytest.raises(ValueError):
        plan_costs_3d(prof, TPU_V5E, bounds, 32, [(0, 1), (1, 1)])


def test_stage_costs_width_threads_through_simulator():
    prof = _skewed_profile()
    costs = plan_costs_3d(prof, TPU_V5E, [(0, 4), (4, 8)], 32,
                          [(1, 2), (1, 2)])
    res = simulate_costs("1f1b", 4, 2, costs)
    assert res.widths == (2, 2)


# ---------------------------------------------------------------------------
# grouped AR release (finer buckets satellite) + 2BW steady state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sched", ["gpipe", "1f1b", "dapple", "zb-h1",
                                   "zb-auto"])
def test_grouped_release_monotone_exposed(sched):
    """Splitting each device's bucket into G per-layer-group buckets
    released as the groups' W ops retire can only help: exposed sync is
    non-increasing in G, the compute makespan untouched."""
    M, N, F, B, ar = 8, 4, 1.0, 2.0, 0.6
    evs = [eval_grad_sync(sched, M, N, F, B, ar, groups=g)
           for g in (1, 2, 4, 8)]
    for a, b in zip(evs, evs[1:]):
        assert b.exposed <= a.exposed + 1e-12
        assert b.compute_makespan == a.compute_makespan
    # with a serial fabric and the uniform drain, G groups release the
    # first sub-bucket (G-1)/G of a drain op earlier: strict improvement
    # whenever anything was exposed
    if evs[0].exposed > 1e-9:
        assert evs[-1].exposed < evs[0].exposed


def test_grouped_release_hetero_path():
    prof = _skewed_profile()
    costs = plan_costs_3d(prof, TPU_V5E, [(0, 3), (3, 5), (5, 8)], 32,
                          [(2, 1), (2, 1), (2, 1)])
    ar = [2e-4, 2e-4, 2e-4]
    evs = [eval_grad_sync_costs("1f1b", 8, 3, costs, ar, groups=g)
           for g in (1, 2, 4)]
    for a, b in zip(evs, evs[1:]):
        assert b.exposed <= a.exposed + 1e-12
        assert b.compute_makespan == a.compute_makespan
    assert evs[0].groups == 1 and evs[-1].groups == 4
    with pytest.raises(ValueError):
        eval_grad_sync("1f1b", 8, 4, 1.0, 2.0, 0.5, groups=0)


def test_2bw_steady_state_sync_free():
    """Double-buffered weights give the AR a full step of slack: exposed
    is zero whenever the fabric drains within one step, and exactly the
    fabric excess beyond it."""
    ev = eval_grad_sync_2bw("1f1b", 8, 4, 1.0, 2.0, 0.6)
    sync = eval_grad_sync("1f1b", 8, 4, 1.0, 2.0, 0.6)
    assert ev.compute_makespan == sync.compute_makespan
    assert ev.exposed == 0.0
    assert sync.exposed > 0.0          # the slack 2BW buys is real
    # fabric-bound regime: the step pays only the excess
    big = eval_grad_sync_2bw("1f1b", 4, 2, 1.0, 2.0, 100.0)
    assert big.overlapped == pytest.approx(200.0)
    assert big.exposed == pytest.approx(200.0 - big.compute_makespan)


# ---------------------------------------------------------------------------
# the 3D explorer
# ---------------------------------------------------------------------------

def _fleet8():
    return homogeneous_fleet(TPU_V5E, 8)


def test_explore3d_beats_pipeline_only_on_skewed_profile():
    """Acceptance pin: with one 8x fat layer, depth cannot balance the
    chain — the per-stage (dp, tp) plan that buys the fat stage width
    strictly beats the best pipeline-only plan at the same device
    count, under the same simulator replay."""
    res = explore3d(_skewed_profile(), _fleet8(), 64)
    assert res.incumbent.pipeline_only
    assert not res.best.pipeline_only
    assert res.best.devices_used <= 8
    assert res.best.predicted_time < res.incumbent.predicted_time
    assert res.speedup_over_1d > 1.5
    # the incumbent is IN the ranked space (structurally never worse)
    assert any(c.pipeline_only for c in res.candidates)
    best_1d = min(c.predicted_time for c in res.candidates
                  if c.pipeline_only)
    assert res.incumbent.predicted_time == best_1d


def test_explore3d_candidate_families():
    res = explore3d(_skewed_profile(), _fleet8(), 64)
    assert any(c.uniform and not c.pipeline_only for c in res.candidates)
    assert any(not c.uniform for c in res.candidates)
    # ranked: predicted times non-decreasing
    times = [c.predicted_time for c in res.candidates]
    assert times == sorted(times)
    # budget respected everywhere
    assert all(c.devices_used <= 8 for c in res.candidates)
    assert all(c.schedule in PLAN3D_SCHEDULES for c in res.candidates)


def test_explore3d_differential_ranking_matches_replay():
    """Randomized differential sweep: every sampled candidate's ranking
    score must equal an independent re-evaluation of its (bounds,
    shards, M, schedule) point through the cost model + simulator
    replay — the ranking IS the replay, no drift between them."""
    prof = _skewed_profile()
    fleet = _fleet8()
    res = explore3d(prof, fleet, 64)
    rng = random.Random(7)
    sample = rng.sample(res.candidates, min(20, len(res.candidates)))
    if res.best not in sample:
        sample.append(res.best)
    for c in sample:
        costs = plan_costs_3d(prof, fleet.base, c.bounds, c.microbatch,
                              c.shards)
        data_bw = fleet.base.axis_bandwidth("data")
        ar_vec = []
        for (s, e), (dp, tp) in zip(c.bounds, c.shards):
            wbytes = sum(prof.layers[k].bytes_weights for k in range(s, e))
            ar_vec.append(0.0 if dp <= 1 else
                          2.0 * (dp - 1) / dp * (wbytes / tp) / data_bw)
        gs = eval_grad_sync_costs(c.schedule, c.M, c.n_stages, costs,
                                  ar_vec)
        assert c.predicted_time == pytest.approx(gs.overlapped, rel=1e-9), c
        assert c.sim_makespan == pytest.approx(gs.compute_makespan,
                                               rel=1e-9), c
        # and the replay agrees with the raw simulator on the makespan
        # (the hetero eval replays under the free-comm async premise)
        sim = simulate_costs(c.schedule, c.M, c.n_stages, costs,
                             comm="free")
        assert c.sim_makespan == pytest.approx(sim.makespan, rel=1e-9), c


def test_explore3d_rejects_bad_inputs():
    fleet = FleetSpec(devices=(TPU_V5E, TPU_V5E, fused_device(TPU_V5E, 2)))
    with pytest.raises(ValueError, match="homogeneous"):
        explore3d(_skewed_profile(), fleet, 64)
    with pytest.raises(ValueError):
        explore3d(_skewed_profile(), _fleet8(), 64,
                  schedules=("1f1b-interleaved",))


def test_auto_plan3d_emits_runnable_uniform_plan():
    from repro.core.autoplan import auto_plan3d
    from repro.configs import get_config
    cfg = get_config("llama3.2-1b").reduced(n_layers=8, d_model=256,
                                            seq=128)
    plan = auto_plan3d(cfg, global_batch=32, seq_len=128, n_devices=8)
    assert plan.stages * plan.tensor * plan.data_axis <= 8
    assert plan.stages <= cfg.n_layers
    assert cfg.n_heads % plan.tensor == 0
    # runnable: the per-replica batch splits into M micro-batches
    assert 32 % plan.data_axis == 0
    assert (32 // plan.data_axis) % plan.n_microbatches == 0
    assert plan.schedule in PLAN3D_SCHEDULES
    assert plan.predicted_step_time > 0.0
    assert len(plan.stage_widths) >= 1
