"""Optimizer, data pipeline, checkpoint, MoE dispatch unit tests."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import layers as L
from repro.optim import AdamW, SGDM, warmup_cosine
from repro.optim.adamw import clip_by_global_norm, global_norm
from repro.checkpoint import restore_checkpoint, save_checkpoint


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_manual_math():
    opt = AdamW(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=None)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    st_ = opt.init(p)
    p1, st1 = opt.update(p, g, st_)
    m = 0.1 * np.array([0.5, -0.5])
    v = 0.01 * np.array([0.25, 0.25])
    mh, vh = m / 0.1, v / 0.01
    want = np.array([1.0, 2.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-6)
    assert int(st1["step"]) == 1


def test_weight_decay_shrinks_params():
    opt = AdamW(lr=0.1, weight_decay=0.5, clip_norm=None)
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = opt.update(p, g, opt.init(p))
    assert float(p1["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert float(n) == pytest.approx(5.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


def test_sgdm_moves_against_gradient():
    opt = SGDM(lr=0.1, momentum=0.0)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([2.0])}
    p1, _ = opt.update(p, g, opt.init(p))
    assert float(p1["w"][0]) == pytest.approx(0.8)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_data_deterministic():
    d1 = SyntheticLM(vocab=128, seq_len=32, global_batch=4, seed=7)
    d2 = SyntheticLM(vocab=128, seq_len=32, global_batch=4, seed=7)
    b1, b2 = d1.batch(3), d2.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_synthetic_data_learnable_structure():
    """Labels follow the bigram table (up to noise): the conditional next-
    token entropy is far below uniform."""
    d = SyntheticLM(vocab=64, seq_len=128, global_batch=16, seed=0,
                    noise=0.05, branch=2)
    b = d.batch(0)
    toks = np.asarray(b["tokens"])
    labs = np.asarray(b["labels"])
    hits = 0
    for r in range(toks.shape[0]):
        for t in range(toks.shape[1]):
            if labs[r, t] in d.table[toks[r, t]]:
                hits += 1
    frac = hits / toks.size
    assert frac > 0.85        # ~95% follow the chain


def test_labels_are_shifted_tokens():
    d = SyntheticLM(vocab=64, seq_len=16, global_batch=2, seed=1)
    b = d.batch(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.array(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, step=42)
    from repro.checkpoint.ckpt import checkpoint_step
    assert checkpoint_step(path) == 42
    back = restore_checkpoint(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# MoE dispatch (gather/scatter path vs naive dense loop)
# ---------------------------------------------------------------------------

def naive_moe(p, x, cfg, act="silu"):
    mo = cfg.moe
    B, T, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(probs, mo.top_k)
    topv = topv / topv.sum(-1, keepdims=True)
    y = jnp.zeros_like(xt, dtype=jnp.float32)
    for e in range(mo.n_routed):
        fe = (jax.nn.silu(xt @ p["we1"][e]) * (xt @ p["we3"][e])) @ p["we2"][e]
        w = jnp.where(topi == e, topv, 0.0).sum(-1)
        y = y + w[:, None] * fe.astype(jnp.float32)
    if "shared" in p:
        y = y + L.mlp(p["shared"], xt, act).astype(jnp.float32)
    return y.reshape(B, T, d).astype(x.dtype)


def test_moe_gather_dispatch_matches_naive():
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, tp=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = L.moe_block(p, x, cfg)
    yn = naive_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yn), atol=2e-5)
    assert float(aux) >= 0


def test_moe_capacity_drops_overflow():
    """With capacity_factor ~0, (almost) every token is dropped -> output
    is just the shared expert."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e-9))
    p = L.init_moe(jax.random.PRNGKey(0), cfg, tp=1, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = L.moe_block(p, x, cfg)
    shared_only = L.mlp(p["shared"], x.reshape(-1, cfg.d_model)).reshape(x.shape)
    # capacity C=1 keeps at most one token per expert; most match shared-only
    diff = np.abs(np.asarray(y) - np.asarray(shared_only)).max(-1)
    assert (diff < 1e-5).mean() > 0.2
