"""Paper Tables 1 & 2 closed forms vs the discrete-event simulator."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

from repro.core import schedules as S
from repro.core.simulator import simulate


@settings(max_examples=40, deadline=None)
@given(M=st.integers(1, 24), N=st.integers(1, 6),
       F=st.floats(0.1, 5.0), B=st.floats(0.1, 5.0))
def test_async_schedules_match_closed_form(M, N, F, B):
    """Table 1: both async schedules give (M+N-1)(F+B) with free comm."""
    for name in ("1F1B-AS", "FBP-AS"):
        sim = simulate(name, M, N, F, B, 0.0)
        ev = S.SCHEDULES[name](M, N, F, B, 0.0, 1.0, 1.0)
        assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)


@settings(max_examples=40, deadline=None)
@given(M=st.integers(1, 24), N=st.integers(1, 6),
       FB=st.floats(0.2, 5.0), SR=st.floats(0.0, 0.15))
def test_1f1b_so_matches_closed_form(M, N, FB, SR):
    """Table 2, 1F1B-SO: doubled warm-up fully hides comm latency."""
    SR = min(SR, FB / 2)     # paper premise: comm hideable under compute
    sim = simulate("1F1B-SO", M, N, FB, FB, SR)
    ev = S.eval_1f1b_so(M, N, FB, FB, SR, 1.0, 1.0)
    assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(N=st.integers(1, 6), FB=st.floats(0.2, 5.0), SR=st.floats(0.0, 0.2))
def test_1f1b_sno_exact_at_single_microbatch(N, FB, SR):
    sim = simulate("1F1B-SNO", 1, N, FB, FB, SR)
    ev = S.eval_1f1b_sno(1, N, FB, FB, SR, 1.0, 1.0)
    assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(M=st.integers(1, 16), N=st.integers(1, 5),
       FB=st.floats(0.2, 5.0), SR=st.floats(0.0, 0.1))
def test_sno_bracket(M, N, FB, SR):
    """The closed-form SNO time sits between SO (full overlap) and the
    simulator's conservative eager-blocking model."""
    so = S.eval_1f1b_so(M, N, FB, FB, SR, 1.0, 1.0).minibatch_time
    sno = S.eval_1f1b_sno(M, N, FB, FB, SR, 1.0, 1.0).minibatch_time
    sim = simulate("1F1B-SNO", M, N, FB, FB, SR).makespan
    assert so <= sno + 1e-9
    assert sno <= sim + 1e-6


@settings(max_examples=30, deadline=None)
@given(M=st.integers(2, 24), N=st.integers(2, 6))
def test_features_memory_counts(M, N):
    """Features-memory rows: peak live activations ~ (N-i+1) for 1F1B and
    ~ 2(N-i+1) for FBP/SO (within one micro-batch, capped by M)."""
    one = simulate("1F1B-AS", M, N, 1.0, 1.0, 0.0)
    two = simulate("FBP-AS", M, N, 1.0, 1.0, 0.0)
    for i in range(N):
        want1 = min(M, N - i)
        want2 = min(M, 2 * (N - i) - 1)
        assert abs(one.peak_live[i] - want1) <= 1
        assert abs(two.peak_live[i] - want2) <= 1
        assert two.peak_live[i] >= one.peak_live[i]


def test_bubble_fraction_shrinks_with_M():
    prev = 1.0
    for M in (2, 4, 8, 16, 32):
        ev = S.eval_1f1b_as(M, 4, 1.0, 1.0, 0.0, 1.0, 1.0)
        assert ev.bubble_fraction < prev
        prev = ev.bubble_fraction
    assert prev == pytest.approx(3 / 35)


def test_bandwidth_demand_ordering():
    """Table 1: FBP-AS demands less bandwidth than 1F1B-AS (2a/(F+B) < a/F)."""
    as_ = S.eval_1f1b_as(8, 4, 1.0, 1.5, 0.0, 10.0, 1.0)
    fbp = S.eval_fbp_as(8, 4, 1.0, 1.5, 0.0, 10.0, 1.0)
    assert fbp.bandwidth_demand < as_.bandwidth_demand


def test_hardware_gating():
    assert S.schedules_for(True) == ("1F1B-AS", "FBP-AS", "DAPPLE", "ZB-H1",
                                     "ZB-H2", "ZB-AUTO", "1F1B-I",
                                     "1F1B-I-ML")
    assert S.schedules_for(False) == ("1F1B-SNO", "1F1B-SO")


def test_heterogeneous_stage_times_supported():
    r = simulate("1F1B-AS", 6, 3, [1.0, 2.0, 1.0], [2.0, 3.0, 2.0], 0.0)
    # bottleneck stage (F+B = 5) dominates: makespan >= M * 5
    assert r.makespan >= 6 * 5.0
