"""Hardened checkpoint layer: NamedTuple rebuild, meta-driven dtype
round-trips, and named-key mismatch errors (instead of bare KeyErrors)."""
import collections
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointMismatch, checkpoint_meta,
                              checkpoint_step, restore_checkpoint,
                              save_checkpoint)

OptState = collections.namedtuple("OptState", ["m", "v"])


def _tree():
    return dict(
        params=dict(w=np.arange(12, dtype=np.float32).reshape(3, 4),
                    b=np.ones((4,), np.float32)),
        opt=dict(m=np.full((3, 4), 0.5, np.float32),
                 step=np.int32(7)),
        scales=[np.float32(1.0), np.float32(2.0)],
    )


def test_roundtrip_bitexact(tmp_path):
    p = str(tmp_path / "ck")
    t = _tree()
    save_checkpoint(p, t, step=7)
    r = restore_checkpoint(p, t)
    for a, b in zip(jax.tree.leaves(r), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint_step(p) == 7


def test_namedtuple_leaves_rebuild(tmp_path):
    """Regression: sequences used to rebuild as ``type(tree)(vals)``,
    which crashes on NamedTuples (their constructor takes fields, not an
    iterable) — optax-style opt states are NamedTuples."""
    p = str(tmp_path / "ck")
    t = dict(opt=OptState(m=np.ones((2, 2), np.float32),
                          v=np.zeros((2, 2), np.float32)),
             lst=[np.float32(3.0)])
    save_checkpoint(p, t)
    r = restore_checkpoint(p, t)
    assert isinstance(r["opt"], OptState)
    assert isinstance(r["lst"], list)
    np.testing.assert_array_equal(np.asarray(r["opt"].m), t["opt"].m)


def test_dtype_restored_from_meta_not_like(tmp_path):
    """bf16 is stored as f32 in the npz (no native encoding) with the
    true dtype in the meta — restore must come back bf16 even when the
    caller's ``like`` tree says f32."""
    p = str(tmp_path / "ck")
    t = dict(w=jnp.asarray(np.arange(8).reshape(2, 4), jnp.bfloat16))
    save_checkpoint(p, t)
    meta = checkpoint_meta(p)
    assert meta["dtypes"]["w"] == "bfloat16"
    like_f32 = dict(w=np.zeros((2, 4), np.float32))
    r = restore_checkpoint(p, like_f32)
    assert r["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(r["w"], np.float32),
                                  np.asarray(t["w"], np.float32))


def test_key_mismatch_names_keys(tmp_path):
    p = str(tmp_path / "ck")
    save_checkpoint(p, dict(a=np.zeros(2, np.float32),
                            b=np.zeros(2, np.float32)))
    with pytest.raises(CheckpointMismatch) as ei:
        restore_checkpoint(p, dict(a=np.zeros(2, np.float32),
                                   c=np.zeros(2, np.float32)))
    msg = str(ei.value)
    assert "c" in msg and "b" in msg
    assert "missing" in msg and "unexpected" in msg


def test_shape_mismatch_names_keys_and_suggests_reshard(tmp_path):
    p = str(tmp_path / "ck")
    save_checkpoint(p, dict(w=np.zeros((4, 2, 8), np.float32)))
    with pytest.raises(CheckpointMismatch) as ei:
        restore_checkpoint(p, dict(w=np.zeros((2, 4, 8), np.float32)))
    msg = str(ei.value)
    assert "w" in msg and "(4, 2, 8)" in msg and "(2, 4, 8)" in msg
    assert "reshard" in msg


def test_extra_meta_roundtrip(tmp_path):
    p = str(tmp_path / "ck")
    extra = dict(layout=dict(stages=4, virtual=2), arch="llama3.2-1b")
    save_checkpoint(p, dict(w=np.zeros(2, np.float32)), step=3, extra=extra)
    meta = checkpoint_meta(p)
    assert meta["step"] == 3
    assert meta["extra"]["layout"]["stages"] == 4
    assert meta["extra"]["arch"] == "llama3.2-1b"


def test_shapedtypestruct_like(tmp_path):
    """``like`` may carry ShapeDtypeStructs — restore never needs real
    arrays on the caller's side."""
    p = str(tmp_path / "ck")
    t = dict(w=np.arange(6, dtype=np.float32).reshape(2, 3))
    save_checkpoint(p, t)
    like = dict(w=jax.ShapeDtypeStruct((2, 3), jnp.float32))
    r = restore_checkpoint(p, like)
    np.testing.assert_array_equal(np.asarray(r["w"]), t["w"])
