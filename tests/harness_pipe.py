"""Multi-device pipeline checks, run as a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests must not set the
flag in-process: the main pytest process keeps 1 device).

Usage: python tests/harness_pipe.py <mode> [arch]
Prints 'OK <metric>' on success, raises otherwise.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
if "--xla_cpu_multi_thread_eigen" not in os.environ["XLA_FLAGS"]:
    # The bit-equality gates here compare gradients across differently
    # structured programs (ticks vs stream, grad_sync end vs overlap).
    # XLA:CPU's multi-threaded Eigen backend picks reduction split
    # points per module, so an unrelated program difference (e.g. the
    # set of trailing all-reduces) can reassociate backward sums at the
    # ulp level; single-threaded contractions make the comparison sound.
    os.environ["XLA_FLAGS"] += " --xla_cpu_multi_thread_eigen=false"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.models import model as M
from repro.pipeline import runtime as RT
from repro.pipeline import stage as ST

TOL = 5e-5


def _mesh(data, stages, tensor, pod=0):
    from repro.launch.mesh import make_mesh
    shape = ((pod,) if pod else ()) + (data, stages, tensor)
    axes = (("pod",) if pod else ()) + ("data", "stage", "tensor")
    return make_mesh(shape, axes)


def _setup(arch, stages, tensor, fsdp=False, aux0=True):
    cfg = get_config(arch).reduced(n_layers=4, d_model=128)
    changes = dict(stages=stages, tensor=tensor, fsdp=fsdp)
    if cfg.moe is not None and aux0:
        changes["moe"] = dataclasses.replace(cfg.moe, router_aux_weight=0.0,
                                             capacity_factor=8.0)
    if cfg.family == "audio":
        changes["n_enc_layers"] = 2
    cfg = dataclasses.replace(cfg, **changes)
    plan = ST.plan_stages(cfg)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    return cfg, plan, params


def _batch(cfg, B, T):
    kt, kl, kf = jax.random.split(jax.random.PRNGKey(3), 3)
    b = dict(tokens=jax.random.randint(kt, (B, T), 0, cfg.vocab),
             labels=jax.random.randint(kl, (B, T), 0, cfg.vocab))
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(kf, (B, 16, cfg.d_model))
    if cfg.family == "vlm":
        b["pos3"] = jnp.broadcast_to(jnp.arange(T)[None, None],
                                     (3, B, T)).astype(jnp.int32)
    return b


def _ref_params(cfg, params, plan=None):
    if plan is not None:
        unstack = lambda a: ST.unstack_chunks(a, plan)[:cfg.n_layers]
    else:
        unstack = lambda a: a.reshape((-1,) + a.shape[2:])[:cfg.n_layers]
    rp = dict(embed=params["embed"],
              layers=jax.tree.map(unstack, params["layers"]),
              final_norm=params["final_norm"])
    if "head" in params:
        rp["head"] = params["head"]
    return rp


def train_equivalence(arch, stages=2, tensor=2, fsdp=False, pod=0,
                      pod_role="data"):
    data = 8 // (stages * tensor * max(1, pod)) or 1
    cfg, plan, params = _setup(arch, stages, tensor, fsdp)
    mesh = _mesh(data, stages, tensor, pod)
    pcfg = RT.PipelineConfig(n_microbatches=2, pod_role=pod_role)
    step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
    batch = _batch(cfg, 8, 32)
    loss, grads = step(params, batch)
    rp = _ref_params(cfg, params)
    ref_loss = M.loss_fn(cfg, rp, batch)
    ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
    assert abs(float(loss) - float(ref_loss)) < 1e-4, \
        (float(loss), float(ref_loss))
    gp = jax.tree.map(
        lambda a: np.asarray(a).reshape((-1,) + a.shape[2:])[:cfg.n_layers],
        grads["layers"])
    gr = jax.tree.map(np.asarray, ref_grads["layers"])
    errs = jax.tree.map(
        lambda a, b: float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)),
        gp, gr)
    worst = max(jax.tree.leaves(errs))
    assert worst < 1e-4, worst
    emb = float(np.max(np.abs(np.asarray(grads["embed"])
                              - np.asarray(ref_grads["embed"]))))
    assert emb < 1e-4 * (np.abs(np.asarray(ref_grads["embed"])).max() + 1), emb
    print(f"OK gerr={worst:.2e}")


def serve_equivalence(arch, stages=2, tensor=2):
    data = 8 // (stages * tensor)
    cfg, plan, params = _setup(arch, stages, tensor)
    mesh = _mesh(data, stages, tensor)
    B, steps, maxlen = 8, 4, 16
    pcfg = RT.PipelineConfig(n_microbatches=2)
    serve, _, cspecs, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                             max_len=maxlen, global_batch=B)
    cache = jax.jit(lambda: RT.init_pipeline_cache(cfg, plan, B, maxlen),
                    out_shardings=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), cspecs))()
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, steps), 0, cfg.vocab)
    got = []
    for t in range(steps):
        b = dict(tokens=toks[:, t:t + 1])
        if cfg.family == "vlm":
            b["pos3"] = jnp.full((3, B, 1), t, jnp.int32)
        lg, cache = serve(params, cache, b)
        got.append(np.asarray(lg[:, 0]))
    rp = _ref_params(cfg, params)
    rcache = M.init_cache(cfg, B, max_len=maxlen)
    errs = []
    for t in range(steps):
        b = dict(tokens=toks[:, t:t + 1])
        if cfg.family == "vlm":
            b["pos3"] = jnp.full((3, B, 1), t, jnp.int32)
        lg, rcache = M.decode_step(cfg, rp, b, rcache)
        errs.append(float(np.max(np.abs(got[t] - np.asarray(lg[:, 0])))))
    assert max(errs) < TOL, errs
    print(f"OK maxerr={max(errs):.2e}")


def train_loss_decreases(arch):
    from repro.launch.train import main as train_main
    losses = train_main(["--arch", arch, "--reduced", "--layers", "2",
                         "--d-model", "128", "--data", "2", "--stages", "2",
                         "--tensor", "2", "--steps", "60", "--batch", "8",
                         "--seq", "64", "--lr", "6e-3", "--log-every", "30"])
    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    assert last < first - 0.3, (first, last)
    print(f"OK loss {first:.3f}->{last:.3f}")


def serve_driver(arch):
    from repro.launch.serve import main as serve_main
    base = ["--arch", arch, "--reduced", "--data", "2", "--stages", "2",
            "--tensor", "2", "--batch", "8", "--prompt-len", "16",
            "--gen", "8"]
    toks = serve_main(base)
    assert toks.shape == (8, 8)
    # interleaved prefill + donated restack handoff must not change tokens
    toks_v2 = serve_main(base + ["--virtual", "2"])
    assert (toks_v2 == toks).all()
    print("OK")


def moe_ep_data(arch="deepseek-v3-671b"):
    train_equivalence(arch, stages=2, tensor=2)


def interleaved_equivalence(arch="llama3.2-1b", stages=2, tensor=2,
                            virtual=2, microbatches=2, schedule="auto",
                            fsdp=0):
    """1F1B-I: V>1 chunked pipeline loss/grads must equal both the V=1
    pipeline and the single-device reference — for every ring schedule
    (streaming and memory-lean) and with fsdp sharding of the chunked
    [S, V, Lc] parameters."""
    import dataclasses as _dc
    data = 8 // (stages * tensor) or 1
    cfg = get_config(arch).reduced(n_layers=stages * virtual, d_model=128)
    cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=virtual,
                      fsdp=bool(fsdp))
    mesh = _mesh(data, stages, tensor)
    plan = ST.plan_stages(cfg)
    assert plan.virtual == virtual and plan.layers_per_stage == 1
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    pcfg = RT.PipelineConfig(n_microbatches=microbatches, schedule=schedule)
    step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
    batch = _batch(cfg, 8, 32)
    loss, grads = step(params, batch)

    # single-device reference
    rp = _ref_params(cfg, params, plan)
    ref_loss = M.loss_fn(cfg, rp, batch)
    ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
    assert abs(float(loss) - float(ref_loss)) < 1e-4, \
        (float(loss), float(ref_loss))
    gp = jax.tree.map(
        lambda a: np.asarray(ST.unstack_chunks(a, plan))[:cfg.n_layers],
        grads["layers"])
    gr = jax.tree.map(np.asarray, ref_grads["layers"])
    errs = jax.tree.map(
        lambda a, b: float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)),
        gp, gr)
    worst = max(jax.tree.leaves(errs))
    assert worst < 1e-4, worst

    # V=1 pipeline on the same weights (re-stacked contiguously)
    cfg1 = _dc.replace(cfg, virtual=1, fsdp=False)
    plan1 = ST.plan_stages(cfg1)
    params1 = dict(rp)
    params1["layers"] = jax.tree.map(
        lambda a: ST._stack_chunks(a, plan1), rp["layers"])
    step1, _ = RT.make_train_step(cfg1, mesh, plan1,
                                  RT.PipelineConfig(
                                      n_microbatches=microbatches))
    loss1, _ = step1(params1, batch)
    assert abs(float(loss) - float(loss1)) < 1e-4, \
        (float(loss), float(loss1))
    print(f"OK gerr={worst:.2e}")


def schedule_equivalence(arch="llama3.2-1b", stages=2, tensor=2,
                         microbatches=4, *schedules):
    """First-class backward ticks: every ring schedule — including the
    early-backward ``dapple`` and the zero-bubble family ``zb_h1`` /
    ``zb_h2`` / ``zb_auto`` (split input-/weight-gradient ticks) — must
    produce loss/grads equal to the single-device reference (and hence
    to each other / to gpipe).  A ``name:K`` schedule runs zb_auto under
    a peak-live cap of K (the PipelineConfig.mem_limit knob).  Runs
    several schedules in one subprocess so the reference is computed
    once."""
    schedules = schedules or ("gpipe", "dapple", "zb_h1", "zb_h2",
                              "zb_auto")
    data = 8 // (stages * tensor) or 1
    cfg, plan, params = _setup(arch, stages, tensor)
    mesh = _mesh(data, stages, tensor)
    batch = _batch(cfg, 8, 32)
    rp = _ref_params(cfg, params)
    ref_loss = float(M.loss_fn(cfg, rp, batch))
    ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
    gr = jax.tree.map(np.asarray, ref_grads["layers"])
    worsts = {}
    for sched in schedules:
        name, _, cap = str(sched).partition(":")
        pcfg = RT.PipelineConfig(n_microbatches=microbatches, schedule=name,
                                 mem_limit=int(cap) if cap else 0)
        step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
        loss, grads = step(params, batch)
        assert abs(float(loss) - ref_loss) < 1e-4, (sched, float(loss),
                                                    ref_loss)
        gp = jax.tree.map(
            lambda a: np.asarray(a).reshape((-1,) + a.shape[2:])
            [:cfg.n_layers], grads["layers"])
        errs = jax.tree.map(
            lambda a, b: float(np.max(np.abs(a - b))
                               / (np.max(np.abs(b)) + 1e-9)), gp, gr)
        worst = max(jax.tree.leaves(errs))
        assert worst < 1e-4, (sched, worst)
        emb = float(np.max(np.abs(np.asarray(grads["embed"])
                                  - np.asarray(ref_grads["embed"]))))
        assert emb < 1e-4 * (np.abs(np.asarray(ref_grads["embed"])).max()
                             + 1), (sched, emb)
        worsts[sched] = worst
    print("OK " + " ".join(f"{k}={v:.2e}" for k, v in worsts.items()))


def stream_equivalence(arch="llama3.2-1b", stages=2, tensor=1,
                       microbatches=4, *schedules):
    """runtime='stream' (gated instruction-stream rings) must produce
    loss/grads BIT-EQUAL to runtime='ticks' — the compiled op sequence
    and every data path are identical; the gated rings skip only slots
    whose carries are dead — and grad-equal to the single-device
    reference, for every ring builder."""
    import dataclasses as _dc
    schedules = schedules or ("gpipe", "1f1b", "dapple", "zb-h1", "zb-h2",
                              "zb-auto", "1f1b-interleaved",
                              "1f1b-interleaved-memlean")
    data = 8 // (stages * tensor) or 1
    mesh = _mesh(data, stages, tensor)
    worsts = {}
    for sched in schedules:
        V = 2 if "interleaved" in str(sched) else 1
        cfg = get_config(arch).reduced(n_layers=max(4, stages * V),
                                       d_model=128)
        cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=V)
        plan = ST.plan_stages(cfg)
        params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
        batch = _batch(cfg, 8, 32)
        rp = _ref_params(cfg, params, plan)
        ref_loss = float(M.loss_fn(cfg, rp, batch))
        ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
        gr = jax.tree.map(np.asarray, ref_grads["layers"])
        outs = {}
        for runtime in ("ticks", "stream"):
            pcfg = RT.PipelineConfig(n_microbatches=microbatches,
                                     schedule=str(sched), runtime=runtime)
            step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
            loss, grads = step(params, batch)
            assert abs(float(loss) - ref_loss) < 1e-4, \
                (sched, runtime, float(loss), ref_loss)
            outs[runtime] = (float(loss), jax.tree.map(np.asarray, grads))
        lt, gt = outs["ticks"]
        ls, gs = outs["stream"]
        assert ls == lt, (sched, ls, lt)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     gs, gt)
        gp = jax.tree.map(
            lambda a: np.asarray(ST.unstack_chunks(a, plan))[:cfg.n_layers],
            gs["layers"])
        errs = jax.tree.map(
            lambda a, b: float(np.max(np.abs(a - b))
                               / (np.max(np.abs(b)) + 1e-9)), gp, gr)
        worst = max(jax.tree.leaves(errs))
        assert worst < 1e-4, (sched, worst)
        worsts[str(sched)] = worst
    print("OK " + " ".join(f"{k}={v:.2e}" for k, v in worsts.items()))


def dp_overlap(arch="llama3.2-1b", stages=4, tensor=1,
               microbatches=4, *schedules):
    """Bubble-filling gradient sync: under ``runtime='stream'`` with
    DP>1, ``grad_sync='overlap'`` (AR bucket ops scheduled into the
    pipeline drain, executed inside the tick scan) must produce
    loss/grads BIT-EQUAL to ``grad_sync='end'`` (the trailing
    full-pytree psum it replaces) — the data-axis sum is the same
    single reduction, only its placement moves — and grad-equal to the
    single-device reference, for every ring builder."""
    import dataclasses as _dc
    schedules = schedules or ("gpipe", "1f1b", "dapple", "zb-h1", "zb-h2",
                              "zb-auto", "1f1b-interleaved",
                              "1f1b-interleaved-memlean")
    data = 8 // (stages * tensor) or 1
    assert data > 1, "dp_overlap needs a data axis: use stages*tensor <= 4"
    mesh = _mesh(data, stages, tensor)
    worsts = {}
    for sched in schedules:
        V = 2 if "interleaved" in str(sched) else 1
        cfg = get_config(arch).reduced(n_layers=max(4, stages * V),
                                       d_model=128)
        cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=V)
        plan = ST.plan_stages(cfg)
        params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
        batch = _batch(cfg, 8, 32)
        rp = _ref_params(cfg, params, plan)
        ref_loss = float(M.loss_fn(cfg, rp, batch))
        ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
        gr = jax.tree.map(np.asarray, ref_grads["layers"])
        outs = {}
        for gsync in ("end", "overlap"):
            pcfg = RT.PipelineConfig(n_microbatches=microbatches,
                                     schedule=str(sched), runtime="stream",
                                     grad_sync=gsync)
            step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
            loss, grads = step(params, batch)
            assert abs(float(loss) - ref_loss) < 1e-4, \
                (sched, gsync, float(loss), ref_loss)
            outs[gsync] = (float(loss), jax.tree.map(np.asarray, grads))
        le, ge = outs["end"]
        lo, go = outs["overlap"]
        assert lo == le, (sched, lo, le)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     go, ge)
        gp = jax.tree.map(
            lambda a: np.asarray(ST.unstack_chunks(a, plan))[:cfg.n_layers],
            go["layers"])
        errs = jax.tree.map(
            lambda a, b: float(np.max(np.abs(a - b))
                               / (np.max(np.abs(b)) + 1e-9)), gp, gr)
        worst = max(jax.tree.leaves(errs))
        assert worst < 1e-4, (sched, worst)
        worsts[str(sched)] = worst
    print("OK " + " ".join(f"{k}={v:.2e}" for k, v in worsts.items()))


def tp_equivalence(arch="llama3.2-1b", stages=2, microbatches=4,
                   *schedules):
    """Uniform-TP execution on the real ``tensor`` axis: a tp=2 plan run
    under BOTH runtimes and the bubble-light ring builders must produce
    grads equal to the single-device reference (and ticks == stream
    bit-equal) — the 3D planner's uniform (dp, tp) candidates are
    executable plans, not just analytic entries."""
    stream_equivalence(arch, stages, 2, microbatches,
                       *(schedules or ("1f1b", "zb-h1")))


def two_bw(arch="llama3.2-1b", stages=2, microbatches=2, steps=4,
           schedule="1f1b"):
    """PipeDream-2BW double-buffered weights: ``grad_sync='2bw'`` must
    apply stale-by-one exactly — step 0 applies its own gradients
    (warmup), step k >= 1 applies step k-1's.  Pinned by replaying the
    run's OWN recorded gradient snapshots (``pending``) through the
    optimizer on the host with the one-step lag and requiring the
    parameter trajectory to match tightly; the grads themselves must
    match the synchronous ``grad_sync='end'`` step, and the trajectory
    must DIFFER from applying each step's fresh grads (the staleness is
    pinned semantics, not noise)."""
    from repro.optim import AdamW
    data = 8 // stages or 1
    assert data > 1, "two_bw needs a data axis"
    cfg, plan, params = _setup(arch, stages, 1)
    mesh = _mesh(data, stages, 1)
    opt = AdamW(lr=1e-2)

    def batch_k(k):
        kt, kl = jax.random.split(jax.random.fold_in(jax.random.PRNGKey(3),
                                                     k))
        return dict(tokens=jax.random.randint(kt, (8, 32), 0, cfg.vocab),
                    labels=jax.random.randint(kl, (8, 32), 0, cfg.vocab))

    pcfg = RT.PipelineConfig(n_microbatches=microbatches, schedule=schedule,
                             runtime="stream", grad_sync="2bw")
    step2, _ = RT.make_train_step(cfg, mesh, plan, pcfg, optimizer=opt)
    p2 = jax.tree.map(lambda a: a.copy(), params)
    st = RT.init_2bw_state(opt.init(p2), p2)
    traj, pendings, losses2 = [], [], []
    host = lambda t: jax.tree.map(np.array, t)   # copy off donated buffers
    for k in range(steps):
        p2, st, m = step2(p2, st, batch_k(k))
        losses2.append(float(m["loss"]))
        traj.append(host(p2))
        pendings.append(host(st["pending"]))

    # grads must equal the synchronous step's grads at the same params
    gstep, _ = RT.make_train_step(cfg, mesh, plan, RT.PipelineConfig(
        n_microbatches=microbatches, schedule=schedule, runtime="stream",
        grad_sync="end"))
    loss0, g0 = gstep(params, batch_k(0))
    assert abs(float(loss0) - losses2[0]) < 1e-5, (float(loss0), losses2[0])
    gerr = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
                           / (np.max(np.abs(np.asarray(b))) + 1e-9)),
        pendings[0], g0)))
    assert gerr < 1e-4, gerr

    # host replay with the one-step lag must reproduce the trajectory
    pr, opt_ref = params, opt.init(params)
    perr = 0.0
    for k in range(steps):
        apply_g = pendings[0] if k == 0 else pendings[k - 1]
        pr, opt_ref = opt.update(pr, apply_g, opt_ref)
        perr = max(perr, max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.max(np.abs(np.asarray(a) - np.asarray(b)))
                               / (np.max(np.abs(np.asarray(b))) + 1e-9)),
            traj[k], pr))))
    assert perr < 1e-6, perr

    # ...and the NON-stale replay (fresh grads each step) must diverge
    ps, opt_s = params, opt.init(params)
    for k in range(steps):
        ps, opt_s = opt.update(ps, pendings[k], opt_s)
    diverged = any(
        float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) > 1e-6
        for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(traj[-1])))
    assert steps < 2 or diverged, "2bw trajectory identical to synchronous"
    print(f"OK gerr={gerr:.2e} perr={perr:.2e} stale-by-one pinned")


def ar_groups(arch="llama3.2-1b", stages=2, groups=2, microbatches=2,
              *schedules):
    """Satellite: per-layer-group AR buckets (``ar_groups=G``, released
    as each group's W retires mid-drain) must be a pure scheduling
    change — loss/grads BIT-EQUAL to the one-bucket overlapped sync;
    every element is still reduced exactly once."""
    import dataclasses as _dc
    schedules = schedules or ("1f1b", "zb-h1")
    data = 8 // stages or 1
    assert data > 1, "ar_groups needs a data axis"
    mesh = _mesh(data, stages, 1)
    worsts = {}
    for sched in schedules:
        # each per-stage chunk must split into `groups` layer groups
        cfg = get_config(arch).reduced(n_layers=max(2, int(groups)) * stages,
                                       d_model=128)
        cfg = _dc.replace(cfg, stages=stages, tensor=1)
        plan = ST.plan_stages(cfg)
        params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
        batch = _batch(cfg, 8, 32)
        rp = _ref_params(cfg, params, plan)
        ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
        gr = jax.tree.map(np.asarray, ref_grads["layers"])
        outs = {}
        for g in (1, int(groups)):
            pcfg = RT.PipelineConfig(n_microbatches=microbatches,
                                     schedule=str(sched), runtime="stream",
                                     grad_sync="overlap", ar_groups=g)
            step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
            loss, grads = step(params, batch)
            outs[g] = (float(loss), jax.tree.map(np.asarray, grads))
        l1, g1 = outs[1]
        lg, gg = outs[int(groups)]
        assert lg == l1, (sched, lg, l1)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                     gg, g1)
        gp = jax.tree.map(
            lambda a: np.asarray(ST.unstack_chunks(a, plan))[:cfg.n_layers],
            gg["layers"])
        errs = jax.tree.map(
            lambda a, b: float(np.max(np.abs(a - b))
                               / (np.max(np.abs(b)) + 1e-9)), gp, gr)
        worst = max(jax.tree.leaves(errs))
        assert worst < 1e-4, (sched, worst)
        worsts[str(sched)] = worst
    print("OK " + " ".join(f"{k}={v:.2e}" for k, v in worsts.items()))


def pos3_ring(arch="qwen2-vl-7b", stages=4, tensor=1, virtual=1,
              microbatches=4, schedule="auto"):
    """Regression for the latent pos3 defect: per-micro-batch DISTINCT
    M-RoPE positions must reach the stage that holds the micro-batch
    (they ride the ppermute ring), not stage 0's micro-batch index."""
    import dataclasses as _dc
    data = 8 // (stages * tensor) or 1
    cfg = get_config(arch).reduced(n_layers=max(4, stages * virtual),
                                   d_model=128)
    cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=virtual)
    assert cfg.family == "vlm", "pos3 regression needs an M-RoPE arch"
    mesh = _mesh(data, stages, tensor)
    plan = ST.plan_stages(cfg)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    pcfg = RT.PipelineConfig(n_microbatches=microbatches, schedule=schedule)
    step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
    B, T = 8, 32
    batch = _batch(cfg, B, T)
    # distinct positions per batch row => distinct per micro-batch
    batch["pos3"] = jax.random.randint(jax.random.PRNGKey(7), (3, B, T),
                                       0, T).astype(jnp.int32)
    loss, grads = step(params, batch)
    rp = _ref_params(cfg, params, plan)
    ref_loss = M.loss_fn(cfg, rp, batch)
    assert abs(float(loss) - float(ref_loss)) < 1e-4, \
        (float(loss), float(ref_loss))
    ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
    gp = jax.tree.map(
        lambda a: np.asarray(ST.unstack_chunks(a, plan))[:cfg.n_layers],
        grads["layers"])
    gr = jax.tree.map(np.asarray, ref_grads["layers"])
    errs = jax.tree.map(
        lambda a, b: float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)),
        gp, gr)
    worst = max(jax.tree.leaves(errs))
    assert worst < 1e-4, worst
    print(f"OK gerr={worst:.2e}")


def prefill_equivalence(arch="llama3.2-1b", stages=2, tensor=2, virtual=2,
                        microbatches=2, schedule="auto"):
    """Interleaved (V>1) pipelined prefill must match the single-device
    reference — run in two segments so the second consumes the KV cache
    the first wrote through the chunked [V, Lc, ...] layout."""
    import dataclasses as _dc
    data = 8 // (stages * tensor) or 1
    cfg = get_config(arch).reduced(n_layers=stages * virtual, d_model=128)
    cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=virtual)
    mesh = _mesh(data, stages, tensor)
    plan = ST.plan_stages(cfg)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    B, P1, P2, maxlen = 8, 8, 8, 32
    pcfg = RT.PipelineConfig(n_microbatches=microbatches, schedule=schedule)
    pre1, _, cspecs, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                            max_len=maxlen, global_batch=B,
                                            q_len=P1)
    pre2, _, _, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                       max_len=maxlen, global_batch=B,
                                       q_len=P2)
    cache = jax.jit(lambda: RT.init_pipeline_cache(cfg, plan, B, maxlen),
                    out_shardings=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), cspecs))()
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, P1 + P2), 0,
                              cfg.vocab)
    lg1, cache = pre1(params, cache, dict(tokens=toks[:, :P1]))
    lg2, cache = pre2(params, cache, dict(tokens=toks[:, P1:]))
    rp = _ref_params(cfg, params, plan)
    rcache = M.init_cache(cfg, B, max_len=maxlen)
    rlg1, rcache = M.decode_step(cfg, rp, dict(tokens=toks[:, :P1]), rcache)
    rlg2, rcache = M.decode_step(cfg, rp, dict(tokens=toks[:, P1:]), rcache)
    e1 = float(np.max(np.abs(np.asarray(lg1[:, 0]) - np.asarray(rlg1[:, -1]))))
    e2 = float(np.max(np.abs(np.asarray(lg2[:, 0]) - np.asarray(rlg2[:, -1]))))
    assert max(e1, e2) < TOL, (e1, e2)
    print(f"OK maxerr={max(e1, e2):.2e}")




def interleaved_decode(arch="llama3.2-1b", stages=2, tensor=2, virtual=2,
                       microbatches=2):
    """One-token pipelined decode on an interleaved (V > 1) plan matches
    the single-device reference — the former NotImplementedError is gone;
    decode ticks replay the same compiled table as prefill."""
    import dataclasses as _dc
    data = 8 // (stages * tensor) or 1
    cfg = get_config(arch).reduced(n_layers=stages * virtual, d_model=128)
    cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=virtual)
    mesh = _mesh(data, stages, tensor)
    plan = ST.plan_stages(cfg)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    B, P1, steps, maxlen = 8, 8, 4, 32
    pcfg = RT.PipelineConfig(n_microbatches=microbatches)
    prefill, _, cspecs, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                               max_len=maxlen,
                                               global_batch=B, q_len=P1)
    serve, _, _, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                        max_len=maxlen, global_batch=B,
                                        q_len=1)
    cache = jax.jit(lambda: RT.init_pipeline_cache(cfg, plan, B, maxlen),
                    out_shardings=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), cspecs))()
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, P1 + steps), 0,
                              cfg.vocab)
    lg, cache = prefill(params, cache, dict(tokens=toks[:, :P1]))
    got = [np.asarray(lg[:, 0])]
    for t in range(steps):
        lg, cache = serve(params, cache, dict(tokens=toks[:, P1 + t:P1 + t + 1]))
        got.append(np.asarray(lg[:, 0]))
    rp = _ref_params(cfg, params, plan)
    rcache = M.init_cache(cfg, B, max_len=maxlen)
    rlg, rcache = M.decode_step(cfg, rp, dict(tokens=toks[:, :P1]), rcache)
    errs = [float(np.max(np.abs(got[0] - np.asarray(rlg[:, -1]))))]
    for t in range(steps):
        rlg, rcache = M.decode_step(
            cfg, rp, dict(tokens=toks[:, P1 + t:P1 + t + 1]), rcache)
        errs.append(float(np.max(np.abs(got[t + 1] - np.asarray(rlg[:, 0])))))
    assert max(errs) < TOL, errs
    print(f"OK maxerr={max(errs):.2e}")


def serve_continuous(arch="llama3.2-1b", stages=2, tensor=2, virtual=1):
    """Continuous batching on the pipelined serve step: overlapping
    requests at staggered arrivals, admitted into cache slots and run as
    mixed chunked-prefill + decode steps, must produce tokens
    bit-identical to each request's solo single-device reference."""
    import copy
    import dataclasses as _dc
    from repro.core import serve_sched as SS
    data = 8 // (stages * tensor) or 1
    if virtual > 1:
        cfg = get_config(arch).reduced(n_layers=stages * virtual, d_model=128)
        cfg = _dc.replace(cfg, stages=stages, tensor=tensor, virtual=virtual)
        plan = ST.plan_stages(cfg)
    else:
        cfg, plan, _ = _setup(arch, stages, tensor)
    mesh = _mesh(data, stages, tensor)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    n_slots, chunk, maxlen = 8, 4, 32
    pcfg = RT.PipelineConfig(n_microbatches=2)
    step, _, cspecs, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                            max_len=maxlen,
                                            global_batch=n_slots,
                                            q_len=chunk)
    cache = jax.jit(lambda: RT.init_pipeline_cache(cfg, plan, n_slots,
                                                   maxlen),
                    out_shardings=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), cspecs))()
    rng = np.random.default_rng(7)
    reqs = [SS.Request(rid=i, prompt=rng.integers(
                0, cfg.vocab, size=pl).tolist(), max_new=4, arrival=a)
            for i, (pl, a) in enumerate([(9, 0), (6, 1), (11, 3), (5, 6)])]

    rp = _ref_params(cfg, params, plan if virtual > 1 else None)

    def solo(req):
        rcache = M.init_cache(cfg, 1, max_len=maxlen)
        lg, rcache = M.decode_step(cfg, rp,
                                   dict(tokens=jnp.asarray([req.prompt])),
                                   rcache)
        t = int(np.asarray(lg[0, -1, :cfg.vocab]).argmax())
        out = [t]
        for _ in range(req.max_new - 1):
            lg, rcache = M.decode_step(cfg, rp,
                                       dict(tokens=jnp.asarray([[t]])),
                                       rcache)
            t = int(np.asarray(lg[0, 0, :cfg.vocab]).argmax())
            out.append(t)
        return out

    refs = {r.rid: solo(r) for r in reqs}
    eng = SS.ContinuousEngine(cfg, step, params, cache, n_slots=n_slots,
                              chunk=chunk)
    done = eng.run(copy.deepcopy(reqs))
    assert len(done) == len(reqs)
    for r in done:
        assert r.generated == refs[r.rid], (r.rid, r.generated, refs[r.rid])
    kinds = [tuple(w.kind for w in sp.work) for sp in eng.step_log]
    assert any("prefill" in k and "decode" in k for k in kinds), kinds
    print(f"OK steps={eng.steps_run} reqs={len(done)} bitident=True")


def elastic_resume(arch="llama3.2-1b"):
    """Kill-and-resume across a device-count change (the survive loop):
    train on an 8-stage pipeline with periodic checkpoints, die mid-run
    via fault injection (exit 17, losing the unsaved tail), then resume
    the SAME job on HALF the devices — 4 stages with 2 virtual chunks
    each, so the checkpoint is host-resharded 8x(V=1) -> 4x(V=2) on
    restore.  The resumed loss trajectory must be BIT-EQUAL to the
    uninterrupted 8-stage reference (deterministic data by step index,
    the optimizer's saved step counter drives the LR schedule, and the
    reshard moves real-layer weights/moments bit-for-bit)."""
    import tempfile
    from repro.launch.train import main as train_main
    d = tempfile.mkdtemp()
    ck = os.path.join(d, "ck")
    common = ["--arch", str(arch), "--reduced", "--layers", "8",
              "--d-model", "64", "--data", "1", "--tensor", "1",
              "--microbatches", "8", "--steps", "12", "--batch", "8",
              "--seq", "32", "--log-every", "100", "--seed", "3"]
    ref = train_main(common + ["--stages", "8"])
    try:
        train_main(common + ["--stages", "8", "--ckpt", ck,
                             "--ckpt-every", "4", "--die-at", "9"])
        raise AssertionError("fault injection did not kill the run")
    except SystemExit as e:
        assert e.code == 17, e.code
    res = train_main(common + ["--stages", "4", "--virtual", "2",
                               "--schedule", "1f1b-interleaved",
                               "--resume", ck])
    # died after step 9, last save at step 8 -> resume covers steps 8..11
    assert len(res) == 4, len(res)
    errs = [abs(a - b) for a, b in zip(res, ref[8:])]
    assert max(errs) == 0.0, (errs, res, ref[8:])
    print(f"OK resumed 8->4(V=2) bit-equal over {len(res)} steps")


def elastic_drift(arch="llama3.2-1b"):
    """Injected cost skew must trip the drift monitor mid-run and
    produce a budget-bounded replan recommendation (the train.py side of
    the elastic loop; plan quality is pinned against the simulator in
    tests/test_drift_replan.py)."""
    import contextlib
    import io
    from repro.launch.train import main as train_main
    out = io.StringIO()
    with contextlib.redirect_stdout(out):
        train_main(["--arch", str(arch), "--reduced", "--layers", "8",
                    "--d-model", "64", "--data", "1", "--stages", "4",
                    "--tensor", "1", "--microbatches", "4", "--steps", "8",
                    "--batch", "4", "--seq", "32", "--log-every", "100",
                    "--drift-every", "2", "--drift-inject", "4,1,1,1",
                    "--drift-threshold", "0.25", "--replan-budget", "20"])
    text = out.getvalue()
    sys.stdout.write(text)
    assert "replan" in text, text
    print("OK drift-triggered replan fired")


def pod_stage_equivalence():
    import dataclasses as _dc
    cfg = get_config("llama3.2-1b").reduced(n_layers=4, d_model=128)
    cfg = _dc.replace(cfg, stages=2, tensor=2)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 1, 2, 2), ("pod", "data", "stage", "tensor"))
    plan = ST.plan_stages(cfg, n_stages=4)
    params = ST.init_stacked_params(cfg, jax.random.PRNGKey(0), plan)
    pcfg = RT.PipelineConfig(n_microbatches=2, pod_role="stage")
    step, _ = RT.make_train_step(cfg, mesh, plan, pcfg)
    batch = _batch(cfg, 8, 32)
    loss, grads = step(params, batch)
    rp = _ref_params(cfg, params)
    ref_loss = M.loss_fn(cfg, rp, batch)
    assert abs(float(loss) - float(ref_loss)) < 1e-4
    ref_grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch))(rp)
    gp = jax.tree.map(
        lambda a: np.asarray(a).reshape((-1,) + a.shape[2:])[:cfg.n_layers],
        grads["layers"])
    gr = jax.tree.map(np.asarray, ref_grads["layers"])
    errs = jax.tree.map(
        lambda a, b: float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)),
        gp, gr)
    worst = max(jax.tree.leaves(errs))
    assert worst < 1e-4, worst
    print(f"OK gerr={worst:.2e}")


def gated_serve(arch):
    import dataclasses as _dc
    tp = 1 if arch in ("mamba2-2.7b", "hymba-1.5b") else 2
    cfg, plan, params = _setup(arch, 2, tp)
    mesh = _mesh(8 // (2 * tp), 2, tp)
    B, steps, maxlen = 8, 4, 16
    pcfg = RT.PipelineConfig(n_microbatches=2, gate_ticks=True)
    serve, _, cspecs, _ = RT.make_serve_step(cfg, mesh, plan, pcfg,
                                             max_len=maxlen, global_batch=B)
    cache = jax.jit(lambda: RT.init_pipeline_cache(cfg, plan, B, maxlen),
                    out_shardings=jax.tree.map(
                        lambda s: NamedSharding(mesh, s), cspecs))()
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, steps), 0, cfg.vocab)
    got = []
    for t in range(steps):
        lg, cache = serve(params, cache, dict(tokens=toks[:, t:t + 1]))
        got.append(np.asarray(lg[:, 0]))
    rp = _ref_params(cfg, params)
    rcache = M.init_cache(cfg, B, max_len=maxlen)
    errs = []
    for t in range(steps):
        lg, rcache = M.decode_step(cfg, rp, dict(tokens=toks[:, t:t + 1]),
                                   rcache)
        errs.append(float(np.max(np.abs(got[t] - np.asarray(lg[:, 0])))))
    assert max(errs) < TOL, errs
    print(f"OK maxerr={max(errs):.2e}")


if __name__ == "__main__":
    mode = sys.argv[1]
    args = [int(a) if a.lstrip("-").isdigit() else a for a in sys.argv[2:]]
    {"train_equivalence": train_equivalence,
     "serve_equivalence": serve_equivalence,
     "train_loss_decreases": train_loss_decreases,
     "serve_driver": serve_driver,
     "moe_ep_data": moe_ep_data,
     "pod_stage_equivalence": pod_stage_equivalence,
     "gated_serve": gated_serve,
     "interleaved_equivalence": interleaved_equivalence,
     "schedule_equivalence": schedule_equivalence,
     "stream_equivalence": stream_equivalence,
     "dp_overlap": dp_overlap,
     "tp_equivalence": tp_equivalence,
     "two_bw": two_bw,
     "ar_groups": ar_groups,
     "pos3_ring": pos3_ring,
     "prefill_equivalence": prefill_equivalence,
     "interleaved_decode": interleaved_decode,
     "serve_continuous": serve_continuous,
     "elastic_resume": elastic_resume,
     "elastic_drift": elastic_drift,
     }[mode](*args)
