"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps and hypothesis-generated cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import mha_flash, ssd
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.models import layers as L

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 3e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("BH,T,S,D,causal", [
    (4, 128, 128, 64, True),
    (2, 256, 256, 64, True),
    (2, 100, 100, 32, True),       # non-block-multiple (padding path)
    (3, 64, 256, 128, False),      # cross-attention shape
    (2, 1, 256, 64, False),        # decode shape
    (1, 512, 512, 128, True),
])
def test_flash_attention_matches_ref(BH, T, S, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, T, D), dtype)
    k = jax.random.normal(ks[1], (BH, S, D), dtype)
    v = jax.random.normal(ks[2], (BH, S, D), dtype)
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=causal,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, scale=D ** -0.5, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_kv_len_masking():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 1, 64))
    k = jax.random.normal(ks[1], (2, 128, 64))
    v = jax.random.normal(ks[2], (2, 128, 64))
    out = flash_attention(q, k, v, scale=0.125, causal=False, kv_len=40,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, scale=0.125, causal=False, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([64, 128, 192]), D=st.sampled_from([32, 64]),
       seed=st.integers(0, 2 ** 16))
def test_flash_attention_hypothesis(T, D, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, T, D))
    k = jax.random.normal(ks[1], (2, T, D))
    v = jax.random.normal(ks[2], (2, T, D))
    out = flash_attention(q, k, v, scale=D ** -0.5, causal=True,
                          block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v, scale=D ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_gqa_wrapper_matches_layer_attend():
    """mha_flash (GQA via kv repeat) == models.layers.attend."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, T, Hq, Hkv, D = 2, 64, 8, 2, 32
    q = jax.random.normal(ks[0], (B, T, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    out = mha_flash(q, k, v, scale=D ** -0.5, causal=True, interpret=True)
    ref = L.attend(q, k, v, scale=D ** -0.5, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (2, 64, 8, 32, 16, 16),
    (1, 128, 4, 64, 32, 32),
    (2, 256, 8, 32, 128, 64),       # mamba2-like state size
    (2, 32, 6, 16, 8, 32),          # chunk > T (single chunk)
])
def test_ssd_scan_matches_sequential_ref(B, T, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, N), dtype)
    Cm = jax.random.normal(ks[4], (B, T, N), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, _ = ssd_scan_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(yr))) + 1e-9
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yr.astype(jnp.float32)))) / scale
    assert err < TOL[dtype], err


@settings(max_examples=8, deadline=None)
@given(T=st.sampled_from([32, 64, 128]), H=st.sampled_from([2, 4]),
       seed=st.integers(0, 2 ** 16))
def test_ssd_hypothesis(T, H, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    B, P, N = 1, 16, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    y = ssd(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    yr, _ = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=5e-4)


def test_model_chunked_ssd_matches_ref():
    """The model's XLA chunked-SSD path (training) equals the sequential
    semantics too (same ground truth as the kernel)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    B, T, H, P, N = 2, 96, 4, 32, 16
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    y, st = L._ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    yr, str_ = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), atol=2e-4,
                               rtol=2e-4)
