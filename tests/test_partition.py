"""Balanced-partition exploration: DP optimality, Eq.(1), comm coarse
graining, memory fine-tuning, heterogeneous clusters."""
import itertools

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 must collect without hypothesis
    from _hypo_shim import given, settings, strategies as st

from repro.core import partition as PT
from repro.core.hardware import (DeviceSpec, V100, VCU118, VCU129,
                                 heterogeneous_cluster, homogeneous_cluster)
from repro.core.profiler import LayerProfile, NetworkProfile, fwd_time, bwd_time


def toy_profile(costs, acts=None, weights=None):
    acts = acts or [1e6] * len(costs)
    weights = weights or [1e6] * len(costs)
    layers = tuple(LayerProfile(name=f"l{i}", flops_fwd=c * 1e9,
                                bytes_weights=w, bytes_act_out=a)
                   for i, (c, a, w) in enumerate(zip(costs, acts, weights)))
    return NetworkProfile("toy", layers, unit="sample")


FAST = DeviceSpec("fast", 100e12, 1e12, 16e9, 100e9, efficiency=1.0)
SLOW = DeviceSpec("slow", 25e12, 1e12, 16e9, 100e9, efficiency=1.0)


@settings(max_examples=30, deadline=None)
@given(costs=st.lists(st.floats(0.1, 10.0), min_size=4, max_size=9),
       n=st.integers(2, 3))
def test_dp_partition_is_optimal(costs, n):
    """The O(L^2 N) DP equals brute force over all contiguous partitions."""
    prof = toy_profile(costs)
    cl = homogeneous_cluster(FAST, n)
    plan = PT.dp_partition(prof, cl, mb=1, overlap=True,
                           include_embed_head=False)
    L = len(costs)
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), n - 1):
        bounds = list(zip((0,) + cuts, cuts + (L,)))
        bott = max(
            PT._range_cost(prof, cl, i, s, e, 1, False).total(True)
            for i, (s, e) in enumerate(bounds))
        best = min(best, bott)
    assert plan.bottleneck == pytest.approx(best, rel=1e-9)


def test_partition_covers_all_layers_contiguously():
    prof = toy_profile([1.0] * 12)
    plan = PT.dp_partition(prof, homogeneous_cluster(FAST, 4), mb=4)
    assert plan.bounds[0][0] == 0 and plan.bounds[-1][1] == 12
    for (s0, e0), (s1, e1) in zip(plan.bounds, plan.bounds[1:]):
        assert e0 == s1 and e0 > s0


def test_heterogeneous_faster_device_gets_more_layers():
    prof = toy_profile([1.0] * 10)
    cl = heterogeneous_cluster([FAST, SLOW])
    plan = PT.dp_partition(prof, cl, mb=1, include_embed_head=False)
    n_fast, n_slow = plan.layers_per_stage()
    assert n_fast > n_slow


def test_eq1_targets_harmonic_mean():
    prof = toy_profile([1.0] * 8)
    cl = heterogeneous_cluster([FAST, SLOW])
    t = PT.eq1_targets(prof, cl, mb=1)
    t_fast = sum(fwd_time(l, FAST, 1) + bwd_time(l, FAST, 1)
                 for l in prof.layers)
    t_slow = sum(fwd_time(l, SLOW, 1) + bwd_time(l, SLOW, 1)
                 for l in prof.layers)
    expect = 1.0 / (1.0 / t_fast + 1.0 / t_slow)
    assert t[0] == pytest.approx(expect)


def test_eq1_partition_close_to_dp():
    prof = toy_profile([1.0] * 16)
    cl = homogeneous_cluster(FAST, 4)
    eq1 = PT.eq1_partition(prof, cl, mb=1)
    dp = PT.dp_partition(prof, cl, mb=1)
    assert eq1.bottleneck <= dp.bottleneck * 1.5 + 1e-12


def test_coarse_cuts_threshold():
    acts = [1e12 if i % 2 == 0 else 1e3 for i in range(8)]
    prof = toy_profile([1.0] * 8, acts=acts)
    cuts = PT.coarse_cuts(prof, a_th=1e4)
    assert cuts == {2, 4, 6}       # cut k allowed iff act of layer k-1 small


def test_dp_respects_allowed_cuts():
    prof = toy_profile([1.0] * 8)
    cl = homogeneous_cluster(FAST, 3)
    plan = PT.dp_partition(prof, cl, mb=1, allowed_cuts={3, 5},
                           include_embed_head=False)
    assert plan.bounds == ((0, 3), (3, 5), (5, 8))


def test_coarse_partition_avoids_comm_bound_boundaries():
    """Only the boundary after layer 5 is cheap; the balanced cut (4) would
    be comm-bound.  The explorer's comm-aware flow (DP with comm in the
    cost, coarse-graining as the search restriction) must choose the cheap
    boundary and end comm-free."""
    costs = [1.0] * 8
    acts = [1e12] * 8
    acts[5] = 1e3                   # cut 6 is the only cheap boundary
    prof = toy_profile(costs, acts=acts)
    dev = DeviceSpec("slowlink", 100e12, 1e12, 16e9, 1e9, efficiency=1.0)
    cl = homogeneous_cluster(dev, 2)
    coarse = PT.coarse_partition(prof, cl, mb=1, overlap=True)
    assert not PT.comm_bound(coarse)
    assert coarse.bounds == ((0, 6), (6, 8))
    # and a plan forced through an expensive boundary IS comm-bound
    forced = PT.dp_partition(prof, cl, mb=1, allowed_cuts={4},
                             include_embed_head=False)
    assert PT.comm_bound(forced)


def test_memory_fine_tune_respects_capacity():
    costs = [1.0] * 8
    weights = [7e9, 2e9] + [0.5e9] * 6     # stage 0 (l0,l1) would blow 16GB
    prof = toy_profile(costs, weights=weights)
    cl = homogeneous_cluster(FAST, 4)
    plan = PT.dp_partition(prof, cl, mb=1, include_embed_head=False)
    tuned, ok = PT.memory_fine_tune(prof, cl, plan, mb=1, feat_mult=1, M=8)
    assert ok
    mem = PT.stage_memory(tuned, 1, 8)
    for m, d in zip(mem, cl.devices):
        assert m <= d.memory_capacity


def test_intra_layer_refine_never_hurts():
    prof = toy_profile([5.0, 1.0, 1.0, 1.0, 1.0, 5.0])
    cl = homogeneous_cluster(FAST, 3)
    plan = PT.dp_partition(prof, cl, mb=1, include_embed_head=False)
    refined = PT.intra_layer_refine(prof, cl, plan, mb=1)
    assert refined.bottleneck <= plan.bottleneck + 1e-12


def test_fpga_specs_from_paper_table5():
    assert VCU129.peak_flops > VCU118.peak_flops        # 12288 vs 6840 DSP
    assert VCU129.memory_capacity > VCU118.memory_capacity
    assert VCU118.async_capable and not V100.async_capable
