"""Serving invariant: cached decode token-by-token == teacher-forced full
forward, for every architecture family (GQA / MLA-absorbed / SSM recurrent
/ hybrid / enc-dec cross-cache).

MoE note: token-choice capacity C scales with the number of tokens in the
pass, so a capacity-dropping full pass is NOT bitwise-reproducible by
1-token decode.  The equivalence tests raise capacity_factor so nothing
drops; capacity-drop behaviour itself is covered in test_substrates.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_config
from repro.models import model as M


def _nodrop(cfg):
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_matches_full_forward(arch):
    cfg = _nodrop(get_config(arch).reduced())
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T, S = 2, 16, 32
    kt, kf = jax.random.split(jax.random.PRNGKey(2))
    toks = jax.random.randint(kt, (B, T), 0, cfg.vocab)
    batch = dict(tokens=toks)
    enc_len = 0
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.d_model))
        enc_len = S
    if cfg.family == "vlm":
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    x, _, _ = M.forward(cfg, params, batch)
    table = params.get("head", params["embed"])
    full_logits = x @ table.T

    cache = M.init_cache(cfg, B, max_len=T, enc_len=enc_len)
    if cfg.family == "audio":
        cache = M.prefill_audio_cache(cfg, params, batch["frames"], cache)
    outs = []
    for t in range(T):
        b = dict(tokens=toks[:, t:t + 1])
        if cfg.family == "vlm":
            b["pos3"] = jnp.full((3, B, 1), t, jnp.int32)
        lg, cache = M.decode_step(cfg, params, b, cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    assert err < 5e-5, (arch, err)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_then_decode_matches(arch):
    """Chunked prefill into the cache, then decode continues correctly."""
    cfg = _nodrop(get_config(arch).reduced())
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0, cfg.vocab)
    x, _, _ = M.forward(cfg, params, dict(tokens=toks))
    table = params.get("head", params["embed"])
    full_logits = x @ table.T

    cache = M.init_cache(cfg, B, max_len=T)
    # prefill first half in one shot
    half = T // 2
    lg, cache = M.decode_step(cfg, params, dict(tokens=toks[:, :half]), cache)
    assert float(jnp.max(jnp.abs(lg[:, -1] - full_logits[:, half - 1]))) < 5e-5
    # then token-by-token
    for t in range(half, T):
        lg, cache = M.decode_step(cfg, params, dict(tokens=toks[:, t:t + 1]),
                                  cache)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t])))
        assert err < 5e-5, (arch, t, err)


# ---------------------------------------------------------------------------
# Continuous-batching invariants (single-device reference serve step).
# ---------------------------------------------------------------------------

SERVE_ARCHS = ["llama3.2-1b", "deepseek-v2-lite-16b"]
MAX_LEN = 48


def _serve_setup(arch):
    from repro.core import serve_sched as SS
    cfg = _nodrop(get_config(arch).reduced())
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    step = SS.make_local_serve_step(cfg)
    return cfg, params, step, SS


def _engine(SS, cfg, step, params, n_slots, chunk):
    cache = M.init_cache(cfg, n_slots, max_len=MAX_LEN)
    return SS.ContinuousEngine(cfg, step, params, cache, n_slots, chunk)


def _solo_reference(cfg, params, prompt, max_new):
    """One-shot prefill + greedy decode of a single request."""
    cache = M.init_cache(cfg, 1, max_len=MAX_LEN)
    lg, cache = M.decode_step(
        cfg, params, dict(tokens=jnp.asarray([prompt], jnp.int32)), cache)
    out = [int(jnp.argmax(lg[0, -1, :cfg.vocab]))]
    while len(out) < max_new:
        nxt = jnp.asarray([[out[-1]]], jnp.int32)
        lg, cache = M.decode_step(cfg, params, dict(tokens=nxt), cache)
        out.append(int(jnp.argmax(lg[0, 0, :cfg.vocab])))
    return out


def _prompts(cfg, lengths, seed=3):
    k = jax.random.PRNGKey(seed)
    out = []
    for n in lengths:
        k, sub = jax.random.split(k)
        out.append(jax.random.randint(sub, (n,), 0, cfg.vocab).tolist())
    return out


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_chunked_prefill_token_identical_to_oneshot(arch):
    """Sarathi-style chunked prefill (several chunk-column bites) must
    produce the same generation as a single one-shot prefill."""
    cfg, params, step, SS = _serve_setup(arch)
    (prompt,) = _prompts(cfg, [11])
    req = lambda: SS.Request(rid=0, prompt=list(prompt), max_new=5)

    chunked = _engine(SS, cfg, step, params, n_slots=2, chunk=4)
    (r_c,) = chunked.run([req()])          # 11 tokens = 4 + 4 + 3 bites
    oneshot = _engine(SS, cfg, step, params, n_slots=2, chunk=16)
    (r_o,) = oneshot.run([req()])          # whole prompt in one bite

    assert r_c.generated == r_o.generated, (r_c.generated, r_o.generated)
    assert r_c.generated == _solo_reference(cfg, params, prompt, 5)


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_decode_invariant_to_arrival_order_and_slots(arch):
    """Each request's tokens must not depend on WHEN it arrived, WHICH
    slot it landed in, or what shares its batch: reversing the arrival
    order permutes the slot assignment, yet per-rid generations must be
    bit-identical (and equal to the solo single-request reference)."""
    cfg, params, step, SS = _serve_setup(arch)
    prompts = _prompts(cfg, [9, 5, 12])
    mk = lambda order: [SS.Request(rid=i, prompt=list(prompts[i]), max_new=4,
                                   arrival=t)
                        for t, i in enumerate(order)]

    runs = {}
    for tag, order in (("fwd", [0, 1, 2]), ("rev", [2, 1, 0])):
        eng = _engine(SS, cfg, step, params, n_slots=4, chunk=4)
        done = eng.run(mk(order))
        runs[tag] = {r.rid: list(r.generated) for r in done}
    slots = {r.rid: r.t_admit for r in mk([2, 1, 0])}
    assert runs["fwd"] == runs["rev"], (runs, slots)
    for i, p in enumerate(prompts):
        assert runs["fwd"][i] == _solo_reference(cfg, params, p, 4), i


@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_slot_reuse_after_retirement(arch):
    """A request admitted into a slot that a retired request vacated must
    generate the same tokens as a fresh-cache run (stale K/V rows are
    causally masked / overwritten, offsets are rewound on admission)."""
    cfg, params, step, SS = _serve_setup(arch)
    p0, p1 = _prompts(cfg, [10, 7])
    eng = _engine(SS, cfg, step, params, n_slots=1, chunk=4)
    done = eng.run([SS.Request(rid=0, prompt=list(p0), max_new=3),
                    SS.Request(rid=1, prompt=list(p1), max_new=3)])
    toks = {r.rid: list(r.generated) for r in done}
    assert done[1].t_admit > done[0].t_done  # rid 1 reused rid 0's slot
    assert toks[0] == _solo_reference(cfg, params, p0, 3)
    assert toks[1] == _solo_reference(cfg, params, p1, 3)


def test_continuous_batching_rejects_recurrent_families():
    """SSM/hybrid state is polluted by padded slot columns — the engine
    and the pipelined step must both refuse those families."""
    from repro.core import serve_sched as SS
    cfg = get_config("mamba2-2.7b").reduced()
    with pytest.raises(ValueError, match="attention-family"):
        SS.ContinuousEngine(cfg, lambda *a: None, {}, {}, 2, 4)
