"""Serving invariant: cached decode token-by-token == teacher-forced full
forward, for every architecture family (GQA / MLA-absorbed / SSM recurrent
/ hybrid / enc-dec cross-cache).

MoE note: token-choice capacity C scales with the number of tokens in the
pass, so a capacity-dropping full pass is NOT bitwise-reproducible by
1-token decode.  The equivalence tests raise capacity_factor so nothing
drops; capacity-drop behaviour itself is covered in test_substrates.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_arch_ids, get_config
from repro.models import model as M


def _nodrop(cfg):
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_matches_full_forward(arch):
    cfg = _nodrop(get_config(arch).reduced())
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T, S = 2, 16, 32
    kt, kf = jax.random.split(jax.random.PRNGKey(2))
    toks = jax.random.randint(kt, (B, T), 0, cfg.vocab)
    batch = dict(tokens=toks)
    enc_len = 0
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.d_model))
        enc_len = S
    if cfg.family == "vlm":
        batch["pos3"] = jnp.broadcast_to(
            jnp.arange(T)[None, None], (3, B, T)).astype(jnp.int32)
    x, _, _ = M.forward(cfg, params, batch)
    table = params.get("head", params["embed"])
    full_logits = x @ table.T

    cache = M.init_cache(cfg, B, max_len=T, enc_len=enc_len)
    if cfg.family == "audio":
        cache = M.prefill_audio_cache(cfg, params, batch["frames"], cache)
    outs = []
    for t in range(T):
        b = dict(tokens=toks[:, t:t + 1])
        if cfg.family == "vlm":
            b["pos3"] = jnp.full((3, B, 1), t, jnp.int32)
        lg, cache = M.decode_step(cfg, params, b, cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - full_logits)))
    assert err < 5e-5, (arch, err)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_then_decode_matches(arch):
    """Chunked prefill into the cache, then decode continues correctly."""
    cfg = _nodrop(get_config(arch).reduced())
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, T), 0, cfg.vocab)
    x, _, _ = M.forward(cfg, params, dict(tokens=toks))
    table = params.get("head", params["embed"])
    full_logits = x @ table.T

    cache = M.init_cache(cfg, B, max_len=T)
    # prefill first half in one shot
    half = T // 2
    lg, cache = M.decode_step(cfg, params, dict(tokens=toks[:, :half]), cache)
    assert float(jnp.max(jnp.abs(lg[:, -1] - full_logits[:, half - 1]))) < 5e-5
    # then token-by-token
    for t in range(half, T):
        lg, cache = M.decode_step(cfg, params, dict(tokens=toks[:, t:t + 1]),
                                  cache)
        err = float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, t])))
        assert err < 5e-5, (arch, t, err)
