"""Differential test: the discrete-event simulator must re-derive every
closed-form ``ScheduleEval`` (paper Tables 1-2 plus the interleaved
``1F1B-I``) over randomized (M, N, V, F, B, SR) grids.

Tolerances: makespans match to float noise for the schedules whose closed
forms are exact under their comm model; peak-live matches the features-
memory row within one activation (the work-conserving greedy scheduler may
run a single op ahead of the idealized order — the seed suite grants
1F1B-AS/FBP-AS the same slack).
"""
import random

import pytest

from repro.core import schedules as S
from repro.core.simulator import simulate

RNG = random.Random(20260730)

GRID = []
for _ in range(60):
    N = RNG.randint(1, 6)
    GRID.append((RNG.randint(N, 24), N, RNG.choice([1, 2, 4]),
                 round(RNG.uniform(0.1, 5.0), 3),
                 round(RNG.uniform(0.1, 5.0), 3),
                 round(RNG.uniform(0.0, 0.15), 3)))


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_async_makespans_match_closed_form(M, N, V, F, B, SR):
    """1F1B-AS / FBP-AS / 1F1B-I(V) all match their closed forms exactly
    under the free comm model."""
    for name in ("1F1B-AS", "FBP-AS"):
        sim = simulate(name, M, N, F, B, 0.0)
        ev = S.SCHEDULES[name](M, N, F, B, 0.0, 1.0, 1.0)
        assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)
    sim = simulate("1F1B-I", M, N, F, B, 0.0, V=V)
    ev = S.eval_1f1b_interleaved(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_peak_live_matches_features_memory_rows(M, N, V, F, B, SR):
    """Simulator peak resident activations == features-memory row (a=1),
    within the one-op-ahead slack of the greedy scheduler."""
    cases = [("1F1B-AS", 1), ("FBP-AS", 1), ("1F1B-I", V)]
    for name, v in cases:
        sim = simulate(name, M, N, F, B, 0.0, V=v)
        ev = (S.eval_1f1b_interleaved(M, N, F, B, 0.0, 1.0, 1.0, V=v)
              if name == "1F1B-I" else
              S.SCHEDULES[name](M, N, F, B, 0.0, 1.0, 1.0))
        for i in range(N):
            # the paper rows are per-steady-state; a mini-batch can never
            # have more than M*V live chunk activations
            want = min(M * v, ev.features_memory[i])
            assert abs(sim.peak_live[i] - want) <= 1, \
                (name, v, i, sim.peak_live, ev.features_memory)


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_sync_schedules_still_bracketed(M, N, V, F, B, SR):
    """Table 2 regression under the latency/blocking comm models."""
    SR_so = min(SR, F / 2, B / 2)  # paper premise: comm hideable
    sim = simulate("1F1B-SO", M, N, F, F, SR_so)
    ev = S.eval_1f1b_so(M, N, F, F, SR_so, 1.0, 1.0)
    assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-6)
    so = S.eval_1f1b_so(M, N, F, B, SR, 1.0, 1.0).minibatch_time
    sno = S.eval_1f1b_sno(M, N, F, B, SR, 1.0, 1.0).minibatch_time
    blk = simulate("1F1B-SNO", M, N, F, B, SR).makespan
    assert so <= sno + 1e-9
    assert sno <= blk + 1e-6


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_interleaved_all_comm_models_no_deadlock(M, N, V, F, B, SR):
    """1F1B-I completes (no deadlock) under all three comm models and the
    makespans are ordered free <= latency <= blocking.  (The bracket is
    the V > 1 story; at V == 1 the latency AND blocking ends are pinned
    EXACTLY by the two closed-form tests below.)"""
    free = simulate("1F1B-I", M, N, F, B, SR, V=V, comm="free").makespan
    lat = simulate("1F1B-I", M, N, F, B, SR, V=V, comm="latency").makespan
    blk = simulate("1F1B-I", M, N, F, B, SR, V=V, comm="blocking").makespan
    assert free <= lat + 1e-9 <= blk + 2e-9


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_interleaved_latency_exact_closed_form(M, N, V, F, B, SR):
    """The 1F1B-I latency-model closed form is EXACT (not a bracket) in
    the comm-hideable regime: free makespan plus SR per critical-path hop
    (2(N-1) fill/drain + the warm-up->steady zigzag + the tight ring
    returns at M == N).  SR is clamped to the hideable premise exactly as
    the seed suite clamps 1F1B-SO's (``min(F, B)/2``)."""
    SR_h = min(SR, 0.95 * S.hideable_sr_1f1b_interleaved(M, N, V, F, B))
    lat = simulate("1F1B-I", M, N, F, B, SR_h, V=V, comm="latency").makespan
    ev = S.eval_1f1b_interleaved_latency(M, N, F, B, SR_h, 1.0, 1.0, V=V)
    assert lat == pytest.approx(ev.minibatch_time, rel=1e-9)
    # the hop count is the whole overhead: subtracting it recovers free
    free = simulate("1F1B-I", M, N, F, B, 0.0, V=V, comm="free").makespan
    hops = S.latency_hops_1f1b_interleaved(M, N, V)
    assert lat - free == pytest.approx(hops * SR_h, abs=1e-9 + 1e-9 * lat)
    # and beyond the premise the closed form is still a lower bound
    lat_full = simulate("1F1B-I", M, N, F, B, SR, V=V,
                        comm="latency").makespan
    ev_full = S.eval_1f1b_interleaved_latency(M, N, F, B, SR, 1.0, 1.0, V=V)
    assert ev_full.minibatch_time <= lat_full + 1e-9


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_interleaved_blocking_exact_closed_form(M, N, V, F, B, SR):
    """The 1F1B-I blocking-model closed form is EXACT (replacing the old
    ``lat <= blk`` bracket) at its premise — V = 1, ``F == B == c``,
    ``SR <= blockable_sr_1f1b_interleaved``: the free makespan plus
    ``g(M, N)`` rendezvous stalls of ``c`` each plus ``h(M, N)`` wire
    hops of SR each, including the depth-3 anomaly row (g = 2M - 2,
    h = 3M + 1).  SR is clamped to the premise exactly as the latency
    pin clamps to ``hideable_sr``.  The clamp also steps off low-order
    rational c/SR ratios (e.g. 455/2): when event times k*c + m*SR
    collide EXACTLY in float, the DES tie-break can legally pick a
    shorter rendezvous order than the generic (tie-free) one the
    closed form describes."""
    c = F
    SR_b = min(SR, 0.95 * S.blockable_sr_1f1b_interleaved(M, N, c, c))
    SR_b *= 0.9973137  # tie-avoiding: no low-order rational ratio to c
    blk = simulate("1F1B-I", M, N, c, c, SR_b, V=1, comm="blocking").makespan
    ev = S.eval_1f1b_interleaved_blocking(M, N, c, c, SR_b, 1.0, 1.0)
    assert blk == pytest.approx(ev.minibatch_time, rel=1e-9)
    # the stall + hop counts are the whole overhead over free comm
    free = simulate("1F1B-I", M, N, c, c, 0.0, V=1, comm="free").makespan
    g = S.blocking_stall_1f1b_interleaved(M, N)
    h = S.blocking_hops_1f1b_interleaved(M, N)
    assert blk - free == pytest.approx(g * c + h * SR_b,
                                       abs=1e-9 + 1e-9 * blk)
    # depth 1-2 rings never leave the affine piece: exact at ANY SR
    if N <= 2:
        big = simulate("1F1B-I", M, N, c, c, 7.3 * c, V=1,
                       comm="blocking").makespan
        assert big - free == pytest.approx(g * c + h * 7.3 * c, rel=1e-9)
    # beyond the SR premise the closed form is still a lower bound
    # (tie-stepped for the same reason: an exact float tie can legally
    # undercut the generic makespan the closed form lower-bounds)
    SR_f = SR * 0.9973137
    blk_full = simulate("1F1B-I", M, N, c, c, SR_f, V=1,
                        comm="blocking").makespan
    ev_full = S.eval_1f1b_interleaved_blocking(M, N, c, c, SR_f, 1.0, 1.0)
    assert ev_full.minibatch_time <= blk_full + 1e-9


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_interleaved_bubble_strictly_below_1f1b_as(M, N, V, F, B, SR):
    """Acceptance: 1F1B-I bubble < 1F1B-AS bubble for V > 1 (N > 1)."""
    base = S.eval_1f1b_as(M, N, F, B, 0.0, 1.0, 1.0)
    ev = S.eval_1f1b_interleaved(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    if V > 1 and N > 1:
        assert ev.bubble_fraction < base.bubble_fraction
        assert ev.minibatch_time < base.minibatch_time
    elif V == 1:
        assert ev.minibatch_time == pytest.approx(base.minibatch_time)


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_memlean_matches_closed_form_and_streaming(M, N, V, F, B, SR):
    """1F1B-I-ML (Megatron memory-lean order): same makespan as streaming
    1F1B-I, peak-live equal to its own closed form, never above the
    streaming row."""
    M = (M // N) * N or N          # memlean grid: M % N == 0
    ml = simulate("1F1B-I-ML", M, N, F, B, 0.0, V=V)
    ev = S.eval_1f1b_interleaved_memlean(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    assert ml.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)
    st = S.eval_1f1b_interleaved(M, N, F, B, 0.0, 1.0, 1.0, V=V)
    assert ml.makespan == pytest.approx(st.minibatch_time, rel=1e-9)
    for i in range(N):
        assert abs(ml.peak_live[i] - ev.features_memory[i]) <= 1
        if V > 1 and M > N:
            # the memory win needs real interleaving and more micro-batches
            # than stages (at M == N the streaming row is already minimal)
            assert ev.features_memory[i] <= st.features_memory[i] + 1e-9


@pytest.mark.parametrize("M,N,V,F,B,SR", GRID)
def test_dapple_and_zb_h1_match_closed_forms(M, N, V, F, B, SR):
    """DAPPLE (early backward == 1F1B rows) and ZB-H1
    (``M(F+B) + (N-1)(F+B/2)``) replay exactly under free comm, ZB-H1's
    bubble strictly below 1F1B's for N > 1, at the same 1F1B peak-live
    row."""
    for name in ("DAPPLE", "ZB-H1"):
        sim = simulate(name, M, N, F, B, 0.0)
        ev = S.SCHEDULES[name](M, N, F, B, 0.0, 1.0, 1.0)
        assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)
        for i in range(N):
            want = min(M, ev.features_memory[i])
            assert abs(sim.peak_live[i] - want) <= 1, (name, sim.peak_live)
    zb = S.eval_zb_h1(M, N, F, B, 0.0, 1.0, 1.0)
    base = S.eval_1f1b_as(M, N, F, B, 0.0, 1.0, 1.0)
    if N > 1:
        assert zb.minibatch_time < base.minibatch_time
        assert zb.bubble_fraction < base.bubble_fraction
        # the saving is exactly the weight-grad half pulled off the
        # drain's critical path
        assert base.minibatch_time - zb.minibatch_time == \
            pytest.approx((N - 1) * B / 2, rel=1e-9)
    else:
        assert zb.minibatch_time == pytest.approx(base.minibatch_time)


ZB_GRID = []
for _ in range(60):
    N = RNG.randint(1, 6)
    ZB_GRID.append((RNG.randint(N, 28), N,
                    round(RNG.uniform(0.1, 5.0), 3),      # F
                    round(RNG.uniform(0.1, 5.0), 3),      # B (input-grad)
                    round(RNG.uniform(0.1, 5.0), 3),      # W (weight-grad)
                    RNG.choice([0, N, N + 1, 2 * N, 2 * N + 3])))


@pytest.mark.parametrize("M,N,F,Bc,Wc,mem_limit", ZB_GRID)
def test_zb_auto_differential_sweep(M, N, F, Bc, Wc, mem_limit):
    """Satellite acceptance sweep over (M, N, F, B, W, mem_limit): the
    automatic zero-bubble scheduler's replayed makespan obeys
    ``zb-auto <= zb-h1 <= 1f1b`` (the portfolio step makes the first
    inequality structural for any cap admitting the 1F1B window, drawn
    here), and its peak-live row never exceeds its cap."""
    from repro.core import schedplan as SP
    cap = mem_limit or None
    plan = SP.build_zb_auto(M, N, costs=(F, Bc, Wc), mem_limit=cap)
    B_full = Bc + Wc
    wf = Wc / B_full
    auto = simulate(plan, M, N, F, B_full, 0.0, w_frac=wf).makespan
    h1 = simulate("zb-h1", M, N, F, B_full, 0.0, w_frac=wf).makespan
    fb = simulate("1f1b", M, N, F, B_full, 0.0).makespan
    assert auto <= h1 + 1e-9 <= fb + 2e-9, (auto, h1, fb)
    caps = [max(1, min(M, mem_limit))] * N if mem_limit else [M] * N
    assert all(p <= c for p, c in zip(plan.peak_live(), caps)), \
        (plan.peak_live(), caps)


@pytest.mark.parametrize("M,N,F,Bc,Wc,mem_limit", ZB_GRID)
def test_zb_h2_closed_form_and_bounds(M, N, F, Bc, Wc, mem_limit):
    """Tentpole pin: ``eval_zb_h2``'s makespan ``M(F+B) + (N-1)F`` is
    differentially EXACT against the op-table replay at the even-split
    design point ``B == 2F`` (for M >= 2N - 1, the regime where the
    static table's W weave fills every drain gap), and a strict lower
    bound — the work-and-fill floor — at arbitrary (F, B)."""
    # design point: B = 2F, i.e. b = w = F — the closed form is exact
    M2 = max(M, 2 * N - 1)
    ev = S.eval_zb_h2(M2, N, F, 2 * F, 0.0, 1.0, 1.0)
    sim = simulate("zb-h2", M2, N, F, 2 * F, 0.0)
    assert sim.makespan == pytest.approx(ev.minibatch_time, rel=1e-9)
    assert ev.minibatch_time == pytest.approx(
        M2 * 3 * F + (N - 1) * F, rel=1e-9)
    # the last device (the makespan carrier) is internally idle-free:
    # all remaining idle is the unavoidable (N-1)F fill ramp
    assert sim.internal_idle[N - 1] == pytest.approx(0.0, abs=1e-9)
    # arbitrary costs: the eval reports the achievable replay, which the
    # work-and-fill floor M(F+B) + (N-1)F bounds from below
    B_full = Bc + Wc
    ev = S.eval_zb_h2(M, N, F, B_full, 0.0, 1.0, 1.0)
    sim = simulate("zb-h2", M, N, F, B_full, 0.0)
    assert ev.minibatch_time == pytest.approx(sim.makespan, rel=1e-9)
    assert M * (F + B_full) + (N - 1) * F <= ev.minibatch_time + 1e-9
    # and ZB-H2's features row is the IR's peak-live replay exactly
    from repro.core import schedplan as SP
    assert list(ev.features_memory) == \
        [float(c) for c in SP.build_zb_h2(M, N).peak_live()]


@pytest.mark.parametrize("M,N,F,Bc,Wc,mem_limit", ZB_GRID)
def test_zb_auto_unbounded_is_bubble_free(M, N, F, Bc, Wc, mem_limit):
    """Acceptance: with an unbounded mem cap the automatic scheduler's
    steady state is bubble-free for M >= 2N — the simulator reports ZERO
    idle inside every device's active window (the only idle left is the
    fill/drain ramp), and the makespan is exactly the work-and-fill
    floor M(F+B) + (N-1)F — at the even-split design point."""
    M = max(M, 2 * N)
    sim = simulate("zb-auto", M, N, F, 2 * F, 0.0)
    assert max(sim.internal_idle) == pytest.approx(0.0, abs=1e-9)
    assert sim.makespan == pytest.approx(M * 3 * F + (N - 1) * F, rel=1e-9)
    # eval_zb_auto reports exactly this replayed makespan + peak rows
    ev = S.eval_zb_auto(M, N, F, 2 * F, 0.0, 1.0, 1.0)
    assert ev.minibatch_time == pytest.approx(sim.makespan, rel=1e-9)
    assert list(ev.features_memory) == [float(p) for p in sim.peak_live]


@pytest.mark.parametrize("M,N,F,Bc,Wc,mem_limit", ZB_GRID)
def test_w_plan_peak_memory_comes_from_the_ir(M, N, F, Bc, Wc, mem_limit):
    """Satellite fix pin: for W-bearing plans the simulator's per-device
    peak memory IS the IR's ``peak_live()`` symbolic replay (single
    source of truth with the closed forms and the runtime's residual
    stash), under every comm model."""
    from repro.core import schedplan as SP
    for name in ("zb-h1", "zb-h2", "zb-auto"):
        plan = SP.build_schedule(name, M, N, 1)
        for comm in ("free", "latency", "blocking"):
            sim = simulate(name, M, N, F, Bc + Wc, 0.05, comm=comm)
            assert sim.peak_live == plan.peak_live(), (name, comm)


def test_zb_family_closed_form_ladder():
    """At the design point the family's makespans tier exactly:
    zb-auto == zb-h2 == M(F+B)+(N-1)F < zb-h1 < dapple == 1f1b,
    with gaps (N-1)B/2 each."""
    M, N, F = 12, 4, 1.0
    B = 2 * F
    auto = S.eval_zb_auto(M, N, F, B, 0.0, 1.0, 1.0).minibatch_time
    h2 = S.eval_zb_h2(M, N, F, B, 0.0, 1.0, 1.0).minibatch_time
    h1 = S.eval_zb_h1(M, N, F, B, 0.0, 1.0, 1.0).minibatch_time
    fb = S.eval_1f1b_as(M, N, F, B, 0.0, 1.0, 1.0).minibatch_time
    assert auto == pytest.approx(h2, rel=1e-12)
    assert h2 == pytest.approx(M * (F + B) + (N - 1) * F, rel=1e-12)
    assert h1 - h2 == pytest.approx((N - 1) * B / 2, rel=1e-9)
    assert fb - h1 == pytest.approx((N - 1) * B / 2, rel=1e-9)


def test_interleaved_requires_streaming_microbatches():
    """M < N cannot stream chunk passes through the ring: explicit error,
    not a deadlock."""
    with pytest.raises(ValueError, match="M >= N"):
        simulate("1F1B-I", 2, 4, 1.0, 1.0, 0.0, V=2)
    with pytest.raises(ValueError, match="M % N"):
        simulate("1F1B-I-ML", 6, 4, 1.0, 1.0, 0.0, V=2)


def test_interleaved_heterogeneous_devices_supported():
    r = simulate("1F1B-I", 6, 3, [1.0, 2.0, 1.0], [2.0, 3.0, 2.0], 0.0, V=2)
    # bottleneck device (F+B = 5) processes 6 micro-batches x 2 chunks of
    # (F+B)/V each: makespan >= M * (F+B)
    assert r.makespan >= 6 * 5.0


def test_order_validation_rejects_bad_V():
    with pytest.raises(ValueError):
        simulate("1F1B-AS", 4, 2, 1.0, 1.0, 0.0, V=2)
    with pytest.raises(ValueError):
        simulate("1F1B-I", 4, 2, 1.0, 1.0, 0.0, V=2, comm="bogus")
