"""Quickstart: BaPipe's automatic exploration in 30 seconds (CPU-only).

Profiles VGG-16 / ResNet-50 / GNMT (the paper's models) and one assigned
transformer, then runs the full BaPipe flow — balanced partition,
communication coarse-graining, memory fine-tuning, schedule selection —
on a GPU cluster and an FPGA cluster.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.explorer import explore
from repro.core.hardware import (V100, VCU118, VCU129, TPU_V5E,
                                 heterogeneous_cluster, homogeneous_cluster)
from repro.core.profiler import (profile_arch, profile_gnmt,
                                 profile_resnet50, profile_vgg16)


def show(title, prof, cluster, minibatch):
    r = explore(prof, cluster, minibatch)
    print(f"\n=== {title} ===")
    print(f"  chosen mode : {r.mode}")
    if r.mode == "pipeline":
        print(f"  schedule    : {r.schedule}  (micro-batches M={r.M})")
        print(f"  partition   : {r.plan.layers_per_stage()} layers/stage")
        print(f"  bottleneck  : {r.plan.bottleneck*1e6:.0f} us/micro-batch")
    print(f"  mini-batch  : {r.minibatch_time*1e3:.2f} ms "
          f"(DP baseline {r.dp_time*1e3:.2f} ms -> "
          f"{r.speedup_over_dp:.2f}x)")


def main():
    show("VGG-16, 4x V100 (paper Table 3)",
         profile_vgg16(), homogeneous_cluster(V100, 4), 128)
    show("ResNet-50, 8x V100 (paper: explorer must answer 'use DP')",
         profile_resnet50(), homogeneous_cluster(V100, 8), 128)
    show("GNMT-8, 4x V100",
         profile_gnmt(8), homogeneous_cluster(V100, 4), 256)
    show("ResNet-50, heterogeneous FPGA cluster (paper Table 6)",
         profile_resnet50(),
         heterogeneous_cluster([VCU129, VCU129, VCU118, VCU118]), 128)
    show("llama3.2-1b @ seq 4096, 16x TPU v5e chips",
         profile_arch(get_config("llama3.2-1b"), seq=4096),
         homogeneous_cluster(TPU_V5E, 16), 256)
    show("deepseek-v2-lite (MoE), 16x TPU v5e chips",
         profile_arch(get_config("deepseek-v2-lite-16b"), seq=4096),
         homogeneous_cluster(TPU_V5E, 16), 256)


if __name__ == "__main__":
    main()
