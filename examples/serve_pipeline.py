"""Pipelined batched serving on CPU (8 virtual devices).

Prefill a batch of prompts through the stage-sharded pipeline, then greedy-
decode with the per-stage KV cache (micro-batches keep every stage busy).

Run:  PYTHONPATH=src python examples/serve_pipeline.py [arch]
Try ``mamba2-2.7b`` for the O(1)-state SSM decode path.
"""
import os, sys
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.2-1b"
    tensor = "1" if arch in ("mamba2-2.7b", "hymba-1.5b") else "2"
    data = "2" if tensor == "2" else "4"
    serve_main([
        "--arch", arch, "--reduced",
        "--data", data, "--stages", "2", "--tensor", tensor,
        "--microbatches", "2",
        "--batch", "8", "--prompt-len", "32", "--gen", "16",
    ])
