"""Design-space exploration: how BaPipe's choices move with the hardware.

Sweeps micro-batch counts and cluster shapes for one architecture and
prints the explorer's decision surface — which schedule wins where, when
DP beats pipelining, and what the memory fine-tuner does under a tight
HBM budget.

Run:  PYTHONPATH=src python examples/explore_cluster.py [arch]
"""
import dataclasses
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.explorer import explore
from repro.core.hardware import TPU_V5E, V100, homogeneous_cluster
from repro.core.profiler import profile_arch
from repro.core.schedules import SCHEDULES
from repro.core.simulator import simulate


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen3-1.7b"
    cfg = get_config(arch)
    prof = profile_arch(cfg, seq=4096)
    print(f"arch={arch}: {cfg.n_layers} layers, "
          f"{prof.total_bytes_weights()/2/1e9:.2f}B params (body)")

    print("\n-- cluster-size sweep (TPU v5e chips, minibatch 256) --")
    for n in (2, 4, 8, 16):
        r = explore(prof, homogeneous_cluster(TPU_V5E, n), 256)
        lps = r.plan.layers_per_stage() if r.plan else "-"
        print(f"  N={n:2d}: {r.mode:13s} sched={str(r.schedule):9s} "
              f"M={r.M:3d} t={r.minibatch_time*1e3:8.2f}ms "
              f"speedup={r.speedup_over_dp:5.2f}x layers/stage={lps}")

    print("\n-- schedule cost surface (N=8, analytic vs simulator) --")
    r = explore(prof, homogeneous_cluster(TPU_V5E, 8), 256,
                consider_dp=False)
    F, B = r.plan.bottleneck_FB()
    SR = max(max(c.comm_in, c.comm_out) for c in r.plan.stage_costs)
    for M in (4, 8, 16, 32):
        row = [f"M={M:3d}"]
        for sched in ("1F1B-AS", "FBP-AS", "1F1B-SNO", "1F1B-SO"):
            ev = SCHEDULES[sched](M, 8, F, B, SR, 1.0, 1.0)
            sim = simulate(sched, M, 8, F, B, SR)
            row.append(f"{sched}:{ev.minibatch_time*1e3:7.2f}ms"
                       f"(sim {sim.makespan*1e3:7.2f})")
        print("  " + "  ".join(row))

    print("\n-- tight-memory fine-tuning (4 GiB HBM per chip) --")
    tight = dataclasses.replace(TPU_V5E, memory_capacity=4 * 1024**3)
    r = explore(prof, homogeneous_cluster(tight, 8), 256, consider_dp=False)
    print(f"  feasible={r.feasible} sched={r.schedule} M={r.M} "
          f"layers/stage={r.plan.layers_per_stage() if r.plan else '-'}")
    print(f"  per-stage memory (GiB): "
          f"{[round(m/1024**3, 2) for m in r.per_stage_memory]}")


if __name__ == "__main__":
    main()
