"""End-to-end pipeline-parallel training on CPU (8 virtual devices).

Trains a reduced llama3.2 through the full BaPipe runtime — data x stage x
tensor mesh, micro-batched 1F1B pipeline, AdamW, synthetic bigram data —
for a few hundred steps and prints the loss curve.  The loss dropping well
below the unigram entropy demonstrates the intra-batch pipeline's
synchronous-training semantics end to end.

Run:  PYTHONPATH=src python examples/train_pipeline.py
(sets XLA_FLAGS itself; ~5 minutes on one CPU core)
"""
import os, sys
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    train_main([
        "--arch", "llama3.2-1b", "--reduced",
        "--layers", "4", "--d-model", "256",
        "--data", "2", "--stages", "2", "--tensor", "2",
        "--microbatches", "2",
        "--steps", "300", "--batch", "8", "--seq", "128",
        "--lr", "6e-3", "--log-every", "20",
        "--ckpt", "/tmp/bapipe_quickstart",
    ])
